//! The Sparx model: an ensemble of M half-space chains fit and scored
//! with the paper's three distributed steps (Algorithms 1–3).
//!
//! Pass structure (the §3.4 claim — **two** data passes to fit, **one**
//! to score, constant-size intermediates), as executed by the default
//! [`ExecMode::Fused`] plan ([`super::plan`]):
//!
//! * **Fit, pass 1**: Step 1 projects every point locally (map only, no
//!   shuffle) and Δmax is reduced from constant-size per-partition
//!   min/max partials (one `aggregate` round).
//! * **Fit, pass 2**: one partition visit flattens the sketch block once
//!   and bins *all M chains* against it ([`Binner::tile_bins_multi`]);
//!   each partition emits one concatenated `[M][L][r][w]` count block
//!   (the map-side combine of Alg. 2's `((level,row,col),1)` pairs,
//!   numerically identical to reduceByKey + collectAsMap); blocks merge
//!   worker-side and cross the network once per worker in a single
//!   tree-aggregate round — M·L·r·w bytes charged once, independent of M.
//! * **Score, one pass**: the CMS ensemble is broadcast once (Alg. 3);
//!   one partition visit bins all chains against the once-flattened
//!   block and folds Eq. (5) per point — min over levels, sum over
//!   chains — emitting `(id, outlierness)` directly.
//!
//! The legacy [`ExecMode::PerChain`] path (one `map_partitions` +
//! `aggregate` round *per chain* on the driver thread pool, per-chain
//! score vectors `zip_map`-summed) is kept for A/B comparison; both
//! paths produce bit-identical models and scores.

use crate::api::validate;
use crate::cluster::dist::Broadcast;
use crate::cluster::{pool, ClusterContext, ClusterError, DistVec, Result};
use crate::data::Dataset;
use crate::hash::bin_hash;
use crate::util::SizeOf;

use super::chain::{Binner, ChainParams, NativeBinner};
use super::cms::CountMinSketch;
use super::plan::{self, ChainSet, ExecMode};
use super::projector::{compute_deltamax, project_dataset, Projector, Sketch};

/// Scoring variants: the paper's Eq. (5) linear extrapolation, and the
/// xStream reference code's log2 form (same argmin per chain, smoother
/// ensemble average). Both are exposed; experiments use `Log2`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreMode {
    /// min_l 2^l · c_l (Eq. 5).
    Extrapolated,
    /// min_l log2(1 + c_l) + l (cmuxstream reference).
    Log2,
}

/// Hyperparameters (§4.1.5 names in comments).
#[derive(Debug, Clone)]
pub struct SparxParams {
    /// Projection count K (0 ⇒ no projection; the paper leaves OSM raw).
    pub k: usize,
    /// Ensemble size M (#components).
    pub num_chains: usize,
    /// Chain length / depth L.
    pub depth: usize,
    /// Subsampling rate for fitting.
    pub sample_rate: f64,
    /// CMS hash tables r (paper: 10).
    pub cms_rows: usize,
    /// CMS buckets per table w (paper: 100).
    pub cms_cols: usize,
    /// Non-zero density of the sign hashes (paper: 1/3).
    pub density: f64,
    pub score_mode: ScoreMode,
    /// Execution plan: fused single-pass (default, paper-faithful) or
    /// the legacy one-round-per-chain path (kept for A/B comparison).
    pub exec_mode: ExecMode,
    pub seed: u64,
}

impl Default for SparxParams {
    fn default() -> Self {
        SparxParams {
            k: 50,
            num_chains: 50,
            depth: 10,
            sample_rate: 1.0,
            cms_rows: 10,
            cms_cols: 100,
            density: 1.0 / 3.0,
            score_mode: ScoreMode::Log2,
            exec_mode: ExecMode::Fused,
            seed: 0x5AB4,
        }
    }
}

impl SparxParams {
    /// Validate the hyperparameters, returning a human-readable reason on
    /// failure. Called by [`SparxModel::fit_with`] (mapped to
    /// `ClusterError::Invalid`) and by the `api` layer (mapped to
    /// `SparxError::InvalidParams`), so degenerate settings fail fast with
    /// a typed error instead of panicking deep in the pipeline.
    pub fn validate(&self) -> std::result::Result<(), String> {
        validate::at_least_one(self.num_chains, "num_chains (M)")?;
        validate::at_least_one(self.depth, "depth (L)")?;
        validate::cms_shape(self.cms_rows, self.cms_cols)?;
        validate::cms_packable(self.cms_rows, self.cms_cols)?;
        validate::unit_interval(self.sample_rate, "sample_rate")?;
        validate::unit_interval(self.density, "density")?;
        Ok(())
    }
}

/// The Eq. (5) / log2 scoring kernel: given a point's precomputed
/// `[L][K]` bin-id block for `chain`, return the min-over-levels
/// outlierness contribution. The single shared implementation behind the
/// per-chain distributed scorer, the fused executor
/// ([`plan::score_fused`]), and the streaming front-end.
#[inline]
pub fn score_bins(chain: &TrainedChain, mode: ScoreMode, bins: &[i32]) -> f64 {
    let k = chain.params.k();
    debug_assert_eq!(bins.len(), chain.params.depth() * k);
    let mut best = f64::INFINITY;
    for (lvl, cms) in chain.cms.iter().enumerate() {
        let c = cms.query(&bins[lvl * k..(lvl + 1) * k]) as f64;
        let v = match mode {
            ScoreMode::Extrapolated => (1u64 << (lvl + 1)) as f64 * c,
            ScoreMode::Log2 => (1.0 + c).log2() + (lvl + 1) as f64,
        };
        if v < best {
            best = v;
        }
    }
    best
}

/// [`score_bins`] with a per-level sparse overlay of absorbed counts on
/// top of the chain's read-only CMS blocks (`overlays[lvl]` keyed by
/// row-major bucket index — see [`CountMinSketch::query_overlaid`]).
/// With empty overlays this is bit-identical to [`score_bins`]; it is
/// what lets the serving front-end share one trained ensemble across
/// shards while each shard owns only its absorbed delta.
#[inline]
pub fn score_bins_overlaid(
    chain: &TrainedChain,
    mode: ScoreMode,
    bins: &[i32],
    overlays: &[std::collections::HashMap<u32, u32>],
) -> f64 {
    let k = chain.params.k();
    debug_assert_eq!(bins.len(), chain.params.depth() * k);
    debug_assert_eq!(overlays.len(), chain.cms.len());
    let mut best = f64::INFINITY;
    for (lvl, cms) in chain.cms.iter().enumerate() {
        let row = &bins[lvl * k..(lvl + 1) * k];
        let counted = if overlays[lvl].is_empty() {
            cms.query(row)
        } else {
            cms.query_overlaid(row, &overlays[lvl])
        };
        let c = counted as f64;
        let v = match mode {
            ScoreMode::Extrapolated => (1u64 << (lvl + 1)) as f64 * c,
            ScoreMode::Log2 => (1.0 + c).log2() + (lvl + 1) as f64,
        };
        if v < best {
            best = v;
        }
    }
    best
}

/// [`score_bins_overlaid`] with the windowed-decay read path: per level
/// the base counts are summed with **two** stacked overlays — the live
/// absorb block (`cur`) and the rotated-out previous window (`prev`) —
/// via [`CountMinSketch::query_overlaid2`]. With every `prev` level
/// empty this is bit-identical to [`score_bins_overlaid`], which keeps
/// the undecayed serve path's scores untouched by the decay feature.
#[inline]
pub fn score_bins_overlaid2(
    chain: &TrainedChain,
    mode: ScoreMode,
    bins: &[i32],
    cur: &[std::collections::HashMap<u32, u32>],
    prev: &[std::collections::HashMap<u32, u32>],
) -> f64 {
    let k = chain.params.k();
    debug_assert_eq!(bins.len(), chain.params.depth() * k);
    debug_assert_eq!(cur.len(), chain.cms.len());
    debug_assert_eq!(prev.len(), chain.cms.len());
    let mut best = f64::INFINITY;
    for (lvl, cms) in chain.cms.iter().enumerate() {
        let row = &bins[lvl * k..(lvl + 1) * k];
        let counted = match (cur[lvl].is_empty(), prev[lvl].is_empty()) {
            (true, true) => cms.query(row),
            (false, true) => cms.query_overlaid(row, &cur[lvl]),
            (true, false) => cms.query_overlaid(row, &prev[lvl]),
            (false, false) => cms.query_overlaid2(row, &cur[lvl], &prev[lvl]),
        };
        let c = counted as f64;
        let v = match mode {
            ScoreMode::Extrapolated => (1u64 << (lvl + 1)) as f64 * c,
            ScoreMode::Log2 => (1.0 + c).log2() + (lvl + 1) as f64,
        };
        if v < best {
            best = v;
        }
    }
    best
}

/// Tile form of [`score_bins`]: adds each point's min-over-levels
/// contribution for `chain` into `totals[i]`. Level-major — per level the
/// whole tile's bin rows are hashed once and resolved through
/// [`CountMinSketch::query_many`], so one CMS block stays cache-hot
/// across the batch instead of all L blocks thrashing per point. The
/// per-point fold visits levels in the same ascending order with the
/// same comparisons as [`score_bins`], so the accumulated totals are
/// bit-identical to the per-point loop (asserted in tests).
pub fn score_bins_tile(
    chain: &TrainedChain,
    mode: ScoreMode,
    bins: &[i32],
    n: usize,
    totals: &mut [f64],
) {
    let k = chain.params.k();
    let l = chain.params.depth();
    debug_assert_eq!(bins.len(), n * l * k);
    debug_assert_eq!(totals.len(), n);
    let mut best = vec![f64::INFINITY; n];
    let mut hashes = Vec::with_capacity(n);
    let mut counts = vec![0u32; n];
    for (lvl, cms) in chain.cms.iter().enumerate() {
        hashes.clear();
        for i in 0..n {
            hashes.push(bin_hash(&bins[(i * l + lvl) * k..(i * l + lvl + 1) * k]));
        }
        cms.query_many(&hashes, &mut counts);
        for (b, &cnt) in best.iter_mut().zip(counts.iter()) {
            let c = cnt as f64;
            let v = match mode {
                ScoreMode::Extrapolated => (1u64 << (lvl + 1)) as f64 * c,
                ScoreMode::Log2 => (1.0 + c).log2() + (lvl + 1) as f64,
            };
            if v < *b {
                *b = v;
            }
        }
    }
    for (t, b) in totals.iter_mut().zip(best) {
        *t += b;
    }
}

/// One trained chain: sampled parameters + per-level CMS counts.
#[derive(Debug, Clone)]
pub struct TrainedChain {
    pub params: ChainParams,
    pub cms: Vec<CountMinSketch>,
}

impl SizeOf for TrainedChain {
    fn size_of(&self) -> usize {
        self.params.size_of() + self.cms.iter().map(SizeOf::size_of).sum::<usize>()
    }
}

/// A fitted Sparx model (driver-resident until broadcast for scoring).
pub struct SparxModel {
    pub params: SparxParams,
    pub projector: Projector,
    pub deltamax: Vec<f32>,
    pub chains: Vec<TrainedChain>,
}

impl SparxModel {
    /// Fit with the native Rust binning backend.
    pub fn fit(ctx: &ClusterContext, data: &Dataset, params: &SparxParams) -> Result<SparxModel> {
        Self::fit_with(ctx, data, params, &NativeBinner)
    }

    /// Fit with an explicit binning backend (native or PJRT).
    pub fn fit_with(
        ctx: &ClusterContext,
        data: &Dataset,
        params: &SparxParams,
        binner: &dyn Binner,
    ) -> Result<SparxModel> {
        params.validate().map_err(ClusterError::Invalid)?;
        let projector = Self::make_projector(data, params);
        Self::fit_with_projector(ctx, data, params, binner, projector)
    }

    /// [`fit_with`](Self::fit_with) against a caller-supplied projector
    /// — the SUOD shared-projection substrate: ensemble members with
    /// compatible `(k, density)` schemas hand in clones of **one**
    /// projector (cheap `Arc` shares of its R matrix) instead of each
    /// materialising its own. The projector must match `params.k` (or be
    /// the identity when `k == 0`); callers own that agreement.
    pub fn fit_with_projector(
        ctx: &ClusterContext,
        data: &Dataset,
        params: &SparxParams,
        binner: &dyn Binner,
        projector: Projector,
    ) -> Result<SparxModel> {
        params.validate().map_err(ClusterError::Invalid)?;
        let proj = project_dataset(ctx, data, &projector)?;
        let deltamax = compute_deltamax(ctx, &proj)?;
        let chains = match params.exec_mode {
            ExecMode::Fused => ChainSet::sample(&deltamax, params).fit(ctx, &proj, binner)?,
            ExecMode::PerChain => Self::fit_chains(ctx, &proj, &deltamax, params, binner)?,
        };
        Ok(SparxModel { params: params.clone(), projector, deltamax, chains })
    }

    pub(crate) fn make_projector(data: &Dataset, params: &SparxParams) -> Projector {
        if params.k == 0 {
            Projector::identity(data.dim())
        } else {
            let p = Projector::new(params.k, params.density);
            // dense schemas get the memoised R (and PJRT operand)
            if !data.schema.names.is_empty() {
                p.with_dense_schema(&data.schema.names)
            } else {
                p
            }
        }
    }

    /// Step 2 over an already-projected DF, one distributed round per
    /// chain (the [`ExecMode::PerChain`] executor; the fused equivalent
    /// is [`ChainSet::fit`]).
    pub fn fit_chains(
        ctx: &ClusterContext,
        proj: &DistVec<Sketch>,
        deltamax: &[f32],
        params: &SparxParams,
        binner: &dyn Binner,
    ) -> Result<Vec<TrainedChain>> {
        plan::check_cms_shape(params.cms_rows, params.cms_cols)?;
        let k = deltamax.len();
        let (l, r, w) = (params.depth, params.cms_rows, params.cms_cols);
        pool::try_run_indexed(ctx.cfg.num_threads, params.num_chains, |m| {
            let mut rng = plan::chain_rng(params.seed, m);
            let chain = ChainParams::sample(deltamax, params.depth, &mut rng);
            // rate ≥ 1 ⇒ no subsample copy (§Perf: the per-chain clone of
            // the whole projected DF dominated fit time at rate=1)
            let sampled_owned;
            let sampled = if params.sample_rate >= 1.0 {
                proj
            } else {
                sampled_owned = proj.sample(ctx, params.sample_rate, params.seed ^ (m as u64))?;
                &sampled_owned
            };
            // map + map-side combine: each partition bins its points
            // (Alg. 2's flatMap of ((row,col),1) pairs) and combines them
            // into one dense [L][r][w] count block — the constant-size
            // intermediate of §3.4, numerically identical to
            // reduceByKey-then-collectAsMap over the raw pairs.
            let partials = sampled.map_partitions(ctx, |_, part| {
                let n = part.len();
                let mut flat = Vec::with_capacity(n * k);
                for sk in part {
                    flat.extend_from_slice(&sk.s);
                }
                let bins = binner.tile_bins(&chain, &flat, n)?;
                let mut counts = vec![0u32; l * r * w];
                plan::accumulate_counts(&bins, n, l, k, r, w, &mut counts);
                Ok(vec![counts])
            })?;
            // reduce: sum the constant-size blocks at the driver
            // (collectAsMap analogue; network charged by `aggregate`)
            let total = partials.aggregate(
                ctx,
                vec![0u32; l * r * w],
                |mut acc, c| {
                    for (a, b) in acc.iter_mut().zip(c.iter()) {
                        *a = a.saturating_add(*b);
                    }
                    acc
                },
                |mut a, b| {
                    for (x, y) in a.iter_mut().zip(&b) {
                        *x = x.saturating_add(*y);
                    }
                    a
                },
            )?;
            let cms: Vec<CountMinSketch> = (0..l)
                .map(|lvl| {
                    CountMinSketch::from_counts(r, w, &total[lvl * r * w..(lvl + 1) * r * w])
                })
                .collect();
            Ok(TrainedChain { params: chain, cms })
        })
    }

    /// Score one sketch against one trained chain (Eq. 5 / log2 variant):
    /// bins the sketch, then delegates to the shared [`score_bins`]
    /// kernel. Used by the single-machine xStream baseline.
    pub fn score_sketch_against(
        chain: &TrainedChain,
        mode: ScoreMode,
        s: &[f32],
        scratch: &mut [f32],
        bins: &mut [i32],
    ) -> f64 {
        chain.params.bins_into(s, scratch, bins);
        score_bins(chain, mode, bins)
    }

    /// Step 3: distributed scoring of a dataset. Returns `(id, outlierness)`
    /// pairs where **higher = more outlying** (the Eq. 5 average negated).
    pub fn score_dataset(&self, ctx: &ClusterContext, data: &Dataset) -> Result<Vec<(u64, f64)>> {
        let proj = project_dataset(ctx, data, &self.projector)?;
        self.score_sketches(ctx, &proj)
    }

    /// Score an already-projected DF with the native backend.
    pub fn score_sketches(
        &self,
        ctx: &ClusterContext,
        proj: &DistVec<Sketch>,
    ) -> Result<Vec<(u64, f64)>> {
        self.score_sketches_with(ctx, proj, &NativeBinner)
    }

    /// Score with an explicit binning backend (native or PJRT),
    /// dispatching on the fitted [`ExecMode`]. Either way the CMS
    /// ensemble is broadcast once (Alg. 3 line 3); the fused plan folds
    /// every chain inside one partition visit, the per-chain plan runs
    /// chains on the driver thread pool and sums their score vectors
    /// distributedly. Results are bit-identical.
    pub fn score_sketches_with(
        &self,
        ctx: &ClusterContext,
        proj: &DistVec<Sketch>,
        binner: &dyn Binner,
    ) -> Result<Vec<(u64, f64)>> {
        match self.params.exec_mode {
            ExecMode::Fused => plan::score_fused(self, ctx, proj, binner),
            ExecMode::PerChain => self.score_per_chain(ctx, proj, binner),
        }
    }

    /// The legacy per-chain scorer (one distributed pass per chain).
    fn score_per_chain(
        &self,
        ctx: &ClusterContext,
        proj: &DistVec<Sketch>,
        binner: &dyn Binner,
    ) -> Result<Vec<(u64, f64)>> {
        let bcast: Broadcast<Vec<TrainedChain>> = Broadcast::new(ctx, self.chains.clone())?;
        let mode = self.params.score_mode;
        let k = self.deltamax.len();
        // Chains run on the thread pool in batches; per-batch results are
        // folded in chain order so the float summation is deterministic
        // while only `num_threads` score vectors are alive at once.
        let mut acc: Option<DistVec<f64>> = None;
        let batch = ctx.cfg.num_threads.max(1);
        let mut start = 0;
        while start < self.chains.len() {
            let count = batch.min(self.chains.len() - start);
            let batch_scores = pool::try_run_indexed(ctx.cfg.num_threads, count, |i| {
                let m = start + i;
                self.score_one_chain(ctx, proj, binner, &bcast, m, mode, k)
            })?;
            for scores in batch_scores {
                acc = Some(match acc.take() {
                    None => scores,
                    Some(prev) => prev.zip_map(ctx, &scores, |a, b| a + b)?,
                });
            }
            start += count;
        }
        let summed = acc.ok_or_else(|| ClusterError::Invalid("no chains".into()))?;
        let m = self.chains.len() as f64;
        // average and negate: higher = more outlying
        let avg = proj.zip_map(ctx, &summed, move |sk, &total| (sk.id, -(total / m)))?;
        avg.collect(ctx)
    }

    #[allow(clippy::too_many_arguments)]
    fn score_one_chain(
        &self,
        ctx: &ClusterContext,
        proj: &DistVec<Sketch>,
        binner: &dyn Binner,
        bcast: &Broadcast<Vec<TrainedChain>>,
        m: usize,
        mode: ScoreMode,
        k: usize,
    ) -> Result<DistVec<f64>> {
        let chains = bcast.value();
        let chain = &chains[m];
        let l = chain.params.depth();
        proj.map_partitions(ctx, |_, part| {
            let n = part.len();
            let mut flat = Vec::with_capacity(n * k);
            for sk in part {
                flat.extend_from_slice(&sk.s);
            }
            let bins = binner.tile_bins(&chain.params, &flat, n)?;
            Ok((0..n)
                .map(|i| score_bins(chain, mode, &bins[i * l * k..(i + 1) * l * k]))
                .collect())
        })
    }

    /// Model footprint (what the driver holds / what scoring broadcasts):
    /// O(M · L · r · w) — constant in n and d, the paper's §3.4 claim.
    pub fn model_bytes(&self) -> usize {
        self.chains.iter().map(SizeOf::size_of).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::data::generators::GisetteGen;

    fn ctx() -> ClusterContext {
        ClusterConfig { num_partitions: 4, num_workers: 2, num_threads: 2, ..Default::default() }
            .build()
    }

    fn tiny_params() -> SparxParams {
        SparxParams {
            k: 8,
            num_chains: 10,
            depth: 6,
            sample_rate: 1.0,
            ..Default::default()
        }
    }

    #[test]
    fn fit_and_score_separates_planted_outliers() {
        let c = ctx();
        let gen = GisetteGen { n: 1200, d: 48, ..Default::default() };
        let ld = gen.generate(&c).unwrap();
        let model = SparxModel::fit(&c, &ld.dataset, &tiny_params()).unwrap();
        let scores = model.score_dataset(&c, &ld.dataset).unwrap();
        assert_eq!(scores.len(), 1200);
        let s: Vec<f64> = {
            let mut v = vec![0.0; 1200];
            for (id, sc) in &scores {
                v[*id as usize] = *sc;
            }
            v
        };
        let auc = crate::metrics::auroc(&s, &ld.labels);
        // tiny config (k=8, M=10, L=6) on a hard benchmark: well above
        // chance is what we assert; the full-scale band is checked by the
        // fig2 experiment (see EXPERIMENTS.md).
        assert!(auc > 0.58, "Sparx should beat chance clearly: AUROC={auc}");
    }

    #[test]
    fn scoring_is_deterministic() {
        let c = ctx();
        let gen = GisetteGen { n: 300, d: 16, ..Default::default() };
        let ld = gen.generate(&c).unwrap();
        let model = SparxModel::fit(&c, &ld.dataset, &tiny_params()).unwrap();
        let a = model.score_dataset(&c, &ld.dataset).unwrap();
        let b = model.score_dataset(&c, &ld.dataset).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn model_size_constant_in_n() {
        let c = ctx();
        let p = tiny_params();
        let small = GisetteGen { n: 200, d: 16, ..Default::default() }.generate(&c).unwrap();
        let large = GisetteGen { n: 2000, d: 16, ..Default::default() }.generate(&c).unwrap();
        let ms = SparxModel::fit(&c, &small.dataset, &p).unwrap();
        let ml = SparxModel::fit(&c, &large.dataset, &p).unwrap();
        assert_eq!(ms.model_bytes(), ml.model_bytes(), "model must be O(MLrw), not O(n)");
    }

    #[test]
    fn subsampled_fit_still_scores_everyone() {
        let c = ctx();
        let gen = GisetteGen { n: 800, d: 24, ..Default::default() };
        let ld = gen.generate(&c).unwrap();
        let p = SparxParams { sample_rate: 0.2, ..tiny_params() };
        let model = SparxModel::fit(&c, &ld.dataset, &p).unwrap();
        let scores = model.score_dataset(&c, &ld.dataset).unwrap();
        assert_eq!(scores.len(), 800, "all points scored even with subsampled fit");
    }

    #[test]
    fn extrapolated_and_log2_agree_on_ranking_direction() {
        let c = ctx();
        let gen = GisetteGen { n: 600, d: 24, ..Default::default() };
        let ld = gen.generate(&c).unwrap();
        let p1 = SparxParams { score_mode: ScoreMode::Log2, ..tiny_params() };
        let p2 = SparxParams { score_mode: ScoreMode::Extrapolated, ..tiny_params() };
        let m1 = SparxModel::fit(&c, &ld.dataset, &p1).unwrap();
        let m2 = SparxModel::fit(&c, &ld.dataset, &p2).unwrap();
        let unpack = |v: Vec<(u64, f64)>| {
            let mut s = vec![0.0; 600];
            for (id, sc) in v {
                s[id as usize] = sc;
            }
            s
        };
        let s1 = unpack(m1.score_dataset(&c, &ld.dataset).unwrap());
        let s2 = unpack(m2.score_dataset(&c, &ld.dataset).unwrap());
        let a1 = crate::metrics::auroc(&s1, &ld.labels);
        let a2 = crate::metrics::auroc(&s2, &ld.labels);
        assert!((a1 - a2).abs() < 0.15, "modes disagree wildly: {a1} vs {a2}");
    }

    #[test]
    fn shuffle_rounds_scale_with_chains_not_points() {
        let p = tiny_params();
        let c1 = ctx();
        let small = GisetteGen { n: 200, d: 16, ..Default::default() }.generate(&c1).unwrap();
        let _ = SparxModel::fit(&c1, &small.dataset, &p).unwrap();
        let rounds_small = c1.ledger.rounds();
        let c2 = ctx();
        let large = GisetteGen { n: 1600, d: 16, ..Default::default() }.generate(&c2).unwrap();
        let _ = SparxModel::fit(&c2, &large.dataset, &p).unwrap();
        assert_eq!(rounds_small, c2.ledger.rounds(), "pass structure must not depend on n");
    }

    /// With the fused plan, fit is one `map_partitions` + one aggregate
    /// round no matter how many chains the ensemble has — the ledger's
    /// round counter after fit must be independent of M (and strictly
    /// smaller than the per-chain path's, which pays one round per chain).
    #[test]
    fn fused_fit_rounds_independent_of_num_chains() {
        let fit_rounds = |m: usize, mode: ExecMode| {
            let c = ctx();
            let ld = GisetteGen { n: 400, d: 16, ..Default::default() }.generate(&c).unwrap();
            let p = SparxParams { num_chains: m, exec_mode: mode, ..tiny_params() };
            let _ = SparxModel::fit(&c, &ld.dataset, &p).unwrap();
            c.ledger.rounds()
        };
        let fused10 = fit_rounds(10, ExecMode::Fused);
        let fused40 = fit_rounds(40, ExecMode::Fused);
        assert_eq!(fused10, fused40, "fused fit rounds must not depend on num_chains");
        let per10 = fit_rounds(10, ExecMode::PerChain);
        let per40 = fit_rounds(40, ExecMode::PerChain);
        assert_eq!(per40 - per10, 30, "per-chain path pays one aggregate round per chain");
        assert!(fused40 < per40, "fused must shuffle in fewer rounds than per-chain");
    }

    /// Fused score is a single partition visit on top of the one-time
    /// ensemble broadcast: scoring adds exactly two ledger rounds
    /// (broadcast + collect) regardless of M.
    #[test]
    fn fused_score_rounds_independent_of_num_chains() {
        let score_rounds = |m: usize| {
            let c = ctx();
            let ld = GisetteGen { n: 400, d: 16, ..Default::default() }.generate(&c).unwrap();
            let p = SparxParams { num_chains: m, ..tiny_params() };
            let model = SparxModel::fit(&c, &ld.dataset, &p).unwrap();
            let before = c.ledger.rounds();
            let _ = model.score_dataset(&c, &ld.dataset).unwrap();
            c.ledger.rounds() - before
        };
        assert_eq!(score_rounds(10), score_rounds(40), "fused score rounds depend on M");
        assert_eq!(score_rounds(10), 2, "broadcast + collect only");
    }

    /// The fused and per-chain executors must agree **bit for bit** on
    /// both the fitted model and the scores (same chain-order float
    /// fold), at full rate and under subsampling.
    #[test]
    fn fused_matches_per_chain_bit_for_bit() {
        for rate in [1.0, 0.3] {
            let c = ctx();
            let ld = GisetteGen { n: 600, d: 24, ..Default::default() }.generate(&c).unwrap();
            let fused_p =
                SparxParams { sample_rate: rate, exec_mode: ExecMode::Fused, ..tiny_params() };
            let per_p =
                SparxParams { sample_rate: rate, exec_mode: ExecMode::PerChain, ..tiny_params() };
            let mf = SparxModel::fit(&c, &ld.dataset, &fused_p).unwrap();
            let mp = SparxModel::fit(&c, &ld.dataset, &per_p).unwrap();
            for (a, b) in mf.chains.iter().zip(&mp.chains) {
                assert_eq!(a.params, b.params, "chain params diverge at rate {rate}");
                assert_eq!(a.cms, b.cms, "CMS counts diverge at rate {rate}");
            }
            let sf = mf.score_dataset(&c, &ld.dataset).unwrap();
            let sp = mp.score_dataset(&c, &ld.dataset).unwrap();
            assert_eq!(sf, sp, "scores diverge at rate {rate}");
        }
    }

    #[test]
    fn identity_mode_for_low_dim() {
        let c = ctx();
        let rows = crate::cluster::DistVec::from_vec(
            &c,
            (0..100)
                .map(|i| crate::data::Row::dense(i, vec![(i % 10) as f32, (i / 10) as f32]))
                .collect(),
        )
        .unwrap();
        let ds = Dataset::new(crate::data::Schema::positional(2), rows);
        let p = SparxParams { k: 0, num_chains: 4, depth: 4, ..Default::default() };
        let model = SparxModel::fit(&c, &ds, &p).unwrap();
        assert_eq!(model.deltamax.len(), 2);
        let scores = model.score_dataset(&c, &ds).unwrap();
        assert_eq!(scores.len(), 100);
    }
}
