//! Count-min sketch (Cormode & Muthukrishnan) — the constant-size
//! approximate counting structure behind each chain level (§2.2.2).
//!
//! `r` hash tables ("rows") × `w` buckets ("cols"). Inserting a bin id
//! increments one bucket per row; querying takes the **minimum** across
//! rows (the least over-estimate — hence count-*min*). In the distributed
//! fit, buckets are filled from the `reduceByKey` output rather than by
//! point-wise insertion, which is numerically identical.

use std::collections::HashMap;

use crate::hash::{bin_hash, cms_bucket_from, BinHash};
use crate::util::SizeOf;

#[derive(Debug, Clone, PartialEq)]
pub struct CountMinSketch {
    r: usize,
    w: usize,
    /// row-major [r][w]
    counts: Vec<u32>,
}

impl CountMinSketch {
    pub fn new(r: usize, w: usize) -> Self {
        assert!(r >= 1 && w >= 1);
        CountMinSketch { r, w, counts: vec![0; r * w] }
    }

    pub fn rows(&self) -> usize {
        self.r
    }

    pub fn cols(&self) -> usize {
        self.w
    }

    /// Point-wise insert (single-machine xStream / streaming front-end).
    #[inline]
    pub fn insert(&mut self, bin: &[i32]) {
        self.insert_hashed(bin_hash(bin));
    }

    /// Insert by precomputed bin hash (hot paths hash once per level).
    #[inline]
    pub fn insert_hashed(&mut self, h: BinHash) {
        for row in 0..self.r {
            let b = cms_bucket_from(h, row as u32, self.w);
            self.counts[row * self.w + b] += 1;
        }
    }

    /// The (row, col) bucket coordinates a bin id hashes to — the paper's
    /// `allCols` (Eq. 6): one `((row, col), 1)` pair per hash table.
    #[inline]
    pub fn all_cols<'a>(&'a self, bin: &'a [i32]) -> impl Iterator<Item = (u32, u32)> + 'a {
        let h = bin_hash(bin);
        (0..self.r as u32).map(move |row| (row, cms_bucket_from(h, row, self.w) as u32))
    }

    /// Estimated count = min over rows.
    #[inline]
    pub fn query(&self, bin: &[i32]) -> u32 {
        self.query_hashed(bin_hash(bin))
    }

    /// Query by precomputed bin hash.
    #[inline]
    pub fn query_hashed(&self, h: BinHash) -> u32 {
        let mut m = u32::MAX;
        for row in 0..self.r {
            let b = cms_bucket_from(h, row as u32, self.w);
            m = m.min(self.counts[row * self.w + b]);
        }
        m
    }

    /// Fill a bucket from the reduce output (total count for (row,col)).
    #[inline]
    pub fn set_bucket(&mut self, row: u32, col: u32, count: u32) {
        self.counts[row as usize * self.w + col as usize] = count;
    }

    /// Build from a reduced dense count block (row-major [r][w]) — the
    /// collectAsMap-equivalent when the map-side combine is dense.
    pub fn from_counts(r: usize, w: usize, counts: &[u32]) -> Self {
        assert_eq!(counts.len(), r * w);
        CountMinSketch { r, w, counts: counts.to_vec() }
    }

    /// Raw bucket counts (row-major [r][w]).
    pub fn counts(&self) -> &[u32] {
        &self.counts
    }

    /// Add into a bucket (merging partial counts).
    #[inline]
    pub fn add_bucket(&mut self, row: u32, col: u32, count: u32) {
        self.counts[row as usize * self.w + col as usize] += count;
    }

    /// Query with a sparse *overlay* of absorbed increments on top of the
    /// base counts: min over rows of `base + overlay`. The overlay is
    /// keyed by the row-major bucket index (`row · w + col`, which fits a
    /// `u32` under the shuffle-key packing limits r < 128, w < 2^20).
    /// With an empty overlay this equals [`query`](Self::query) exactly —
    /// the serving front-end's Arc-shared ensemble depends on that
    /// bit-identity.
    #[inline]
    pub fn query_overlaid(&self, bin: &[i32], overlay: &HashMap<u32, u32>) -> u32 {
        let h = bin_hash(bin);
        let mut m = u32::MAX;
        for row in 0..self.r {
            let idx = row * self.w + cms_bucket_from(h, row as u32, self.w);
            let v = self.counts[idx] + overlay.get(&(idx as u32)).copied().unwrap_or(0);
            if v < m {
                m = v;
            }
        }
        m
    }

    /// Record one insertion into a sparse overlay *instead of* the base
    /// counts — the serving absorb path, where the trained counts are
    /// shared read-only across shards and each shard owns only its delta.
    /// `query_overlaid` after `overlay_insert` equals `query` after
    /// [`insert`](Self::insert) on an owned copy, bit for bit.
    #[inline]
    pub fn overlay_insert(&self, bin: &[i32], overlay: &mut HashMap<u32, u32>) {
        let h = bin_hash(bin);
        for row in 0..self.r {
            let idx = (row * self.w + cms_bucket_from(h, row as u32, self.w)) as u32;
            *overlay.entry(idx).or_insert(0) += 1;
        }
    }

    /// Merge another CMS of identical shape (distributed partial merge).
    pub fn merge(&mut self, other: &CountMinSketch) {
        assert_eq!((self.r, self.w), (other.r, other.w));
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }

    /// Total insertions (any row sums to it).
    pub fn total(&self) -> u64 {
        self.counts[..self.w].iter().map(|&c| c as u64).sum()
    }
}

impl SizeOf for CountMinSketch {
    fn size_of(&self) -> usize {
        std::mem::size_of::<Self>() + self.counts.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn never_underestimates() {
        let mut cms = CountMinSketch::new(4, 64);
        let mut rng = Rng::new(1);
        let mut truth = std::collections::HashMap::new();
        for _ in 0..2000 {
            let bin = vec![rng.below(30) as i32, rng.below(5) as i32];
            *truth.entry(bin.clone()).or_insert(0u32) += 1;
            cms.insert(&bin);
        }
        for (bin, &c) in &truth {
            assert!(cms.query(bin) >= c, "underestimate for {bin:?}");
        }
    }

    #[test]
    fn exact_when_sparse() {
        // few distinct keys, wide table → min count is exact w.h.p.
        let mut cms = CountMinSketch::new(10, 1000);
        for i in 0..20 {
            for _ in 0..=i {
                cms.insert(&[i as i32]);
            }
        }
        for i in 0..20i32 {
            assert_eq!(cms.query(&[i]), i as u32 + 1);
        }
    }

    #[test]
    fn unseen_bins_query_zero_when_sparse() {
        let mut cms = CountMinSketch::new(10, 1024);
        for i in 0..10i32 {
            cms.insert(&[i]);
        }
        // with 10 keys in 1024 buckets × 10 rows, an unseen key collides in
        // all 10 rows with probability ≈ (10/1024)^10 ≈ 0
        assert_eq!(cms.query(&[999]), 0);
    }

    #[test]
    fn distributed_fill_equals_pointwise() {
        // simulate the flatMap/reduceByKey path and compare to inserts
        let mut direct = CountMinSketch::new(5, 50);
        let mut via_reduce = CountMinSketch::new(5, 50);
        let mut rng = Rng::new(3);
        let mut pairs: std::collections::HashMap<(u32, u32), u32> = Default::default();
        for _ in 0..500 {
            let bin = vec![rng.below(40) as i32, rng.below(40) as i32];
            direct.insert(&bin);
            for rc in via_reduce.all_cols(&bin).collect::<Vec<_>>() {
                *pairs.entry(rc).or_insert(0) += 1;
            }
        }
        for ((row, col), c) in pairs {
            via_reduce.set_bucket(row, col, c);
        }
        assert_eq!(direct, via_reduce);
    }

    /// The serving-absorb contract: inserting into a sparse overlay over
    /// read-only base counts queries bit-identically to inserting into an
    /// owned copy of the counts.
    #[test]
    fn overlay_insert_and_query_match_in_place_mutation() {
        let mut owned = CountMinSketch::new(6, 64);
        let shared = owned.clone(); // the "trained" base, never mutated
        let mut overlay: HashMap<u32, u32> = HashMap::new();
        let mut rng = Rng::new(17);
        let mut bins = Vec::new();
        for _ in 0..400 {
            let bin = vec![rng.below(50) as i32, rng.below(7) as i32];
            owned.insert(&bin);
            shared.overlay_insert(&bin, &mut overlay);
            bins.push(bin);
        }
        for bin in &bins {
            assert_eq!(owned.query(bin), shared.query_overlaid(bin, &overlay));
        }
        // unseen bins agree too, and an empty overlay is a plain query
        assert_eq!(owned.query(&[-7, 99]), shared.query_overlaid(&[-7, 99], &overlay));
        let empty: HashMap<u32, u32> = HashMap::new();
        for bin in bins.iter().take(20) {
            assert_eq!(shared.query(bin), shared.query_overlaid(bin, &empty));
        }
    }

    #[test]
    fn merge_adds() {
        let mut a = CountMinSketch::new(2, 8);
        let mut b = CountMinSketch::new(2, 8);
        a.insert(&[1]);
        b.insert(&[1]);
        b.insert(&[2]);
        a.merge(&b);
        assert_eq!(a.query(&[1]), 2);
        assert_eq!(a.total(), 3);
    }

    #[test]
    #[should_panic]
    fn merge_shape_mismatch_panics() {
        let mut a = CountMinSketch::new(2, 8);
        let b = CountMinSketch::new(2, 9);
        a.merge(&b);
    }
}
