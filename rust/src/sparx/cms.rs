//! Count-min sketch (Cormode & Muthukrishnan) — the constant-size
//! approximate counting structure behind each chain level (§2.2.2).
//!
//! `r` hash tables ("rows") × `w` buckets ("cols"). Inserting a bin id
//! increments one bucket per row; querying takes the **minimum** across
//! rows (the least over-estimate — hence count-*min*). In the distributed
//! fit, buckets are filled from the `reduceByKey` output rather than by
//! point-wise insertion, which is numerically identical.
//!
//! Two hot-path properties of this implementation:
//! * **Branch-free bucket derivation.** Each operation hashes the bin
//!   once ([`bin_hash`]) and walks the `r` row buckets with
//!   [`BucketWalk`] — two modulos total instead of one per row, bucket
//!   indices bit-identical to the per-row formula.
//! * **Quantized counters.** Counts are stored at the narrowest of
//!   `u8`/`u16`/`u32` that holds the current maximum, promoting in place
//!   when a count outgrows the width (values stay exact, so queries are
//!   bit-identical to a `u32` sketch). Arithmetic saturates at
//!   `u32::MAX` instead of wrapping — a wrapped hot bucket would make
//!   the hottest bin look like an outlier. Typical trained sketches fit
//!   in `u8`/`u16`, shrinking serve residency and artifacts 2–4×.

use std::collections::HashMap;

use crate::hash::{bin_hash, BinHash, BucketWalk};
use crate::util::SizeOf;

/// Width-quantized bucket storage. All widths hold the exact same
/// logical `u32` values; the enum only changes the bytes spent per
/// bucket. Promotion (widening) preserves every count, so the storage
/// width is unobservable through the query API.
#[derive(Debug, Clone)]
enum Counters {
    U8(Vec<u8>),
    U16(Vec<u16>),
    U32(Vec<u32>),
}

impl Counters {
    fn zeros(len: usize) -> Counters {
        Counters::U8(vec![0; len])
    }

    #[inline]
    fn get(&self, idx: usize) -> u32 {
        match self {
            Counters::U8(v) => v[idx] as u32,
            Counters::U16(v) => v[idx] as u32,
            Counters::U32(v) => v[idx],
        }
    }

    fn len(&self) -> usize {
        match self {
            Counters::U8(v) => v.len(),
            Counters::U16(v) => v.len(),
            Counters::U32(v) => v.len(),
        }
    }

    /// Bits per bucket at the current quantization width.
    fn bits(&self) -> u32 {
        match self {
            Counters::U8(_) => 8,
            Counters::U16(_) => 16,
            Counters::U32(_) => 32,
        }
    }

    /// Widen one step, copying every count exactly.
    fn promote(&mut self) {
        *self = match self {
            Counters::U8(v) => Counters::U16(v.iter().map(|&x| x as u16).collect()),
            Counters::U16(v) => Counters::U32(v.iter().map(|&x| x as u32).collect()),
            Counters::U32(_) => return,
        };
    }

    /// Store `v` at `idx`, promoting until the width holds it (the
    /// overflow escape: a `u32` count always fits eventually).
    #[inline]
    fn set(&mut self, idx: usize, v: u32) {
        loop {
            match self {
                Counters::U8(b) if v <= u8::MAX as u32 => {
                    b[idx] = v as u8;
                    return;
                }
                Counters::U16(b) if v <= u16::MAX as u32 => {
                    b[idx] = v as u16;
                    return;
                }
                Counters::U32(b) => {
                    b[idx] = v;
                    return;
                }
                _ => {}
            }
            self.promote();
        }
    }
}

#[derive(Debug, Clone)]
pub struct CountMinSketch {
    r: usize,
    w: usize,
    /// row-major [r][w], width-quantized
    counts: Counters,
}

/// Equality is over logical counts (and shape) — two sketches with the
/// same counts compare equal even at different quantization widths.
impl PartialEq for CountMinSketch {
    fn eq(&self, other: &Self) -> bool {
        self.r == other.r
            && self.w == other.w
            && (0..self.r * self.w).all(|i| self.counts.get(i) == other.counts.get(i))
    }
}

impl CountMinSketch {
    pub fn new(r: usize, w: usize) -> Self {
        assert!(r >= 1 && w >= 1);
        CountMinSketch { r, w, counts: Counters::zeros(r * w) }
    }

    pub fn rows(&self) -> usize {
        self.r
    }

    pub fn cols(&self) -> usize {
        self.w
    }

    /// Point-wise insert (single-machine xStream / streaming front-end).
    #[inline]
    pub fn insert(&mut self, bin: &[i32]) {
        self.insert_hashed(bin_hash(bin));
    }

    /// Insert by precomputed bin hash (hot paths hash once per level).
    /// Saturates at `u32::MAX` instead of wrapping.
    #[inline]
    pub fn insert_hashed(&mut self, h: BinHash) {
        let mut walk = BucketWalk::new(h, self.w);
        let mut base = 0usize;
        for _ in 0..self.r {
            let idx = base + walk.next_bucket();
            let v = self.counts.get(idx).saturating_add(1);
            self.counts.set(idx, v);
            base += self.w;
        }
    }

    /// Batched insert: one hash per bin done by the caller, buckets
    /// derived branch-free per hash.
    pub fn insert_many(&mut self, hashes: &[BinHash]) {
        for &h in hashes {
            self.insert_hashed(h);
        }
    }

    /// The (row, col) bucket coordinates a bin id hashes to — the paper's
    /// `allCols` (Eq. 6): one `((row, col), 1)` pair per hash table.
    #[inline]
    pub fn all_cols<'a>(&'a self, bin: &'a [i32]) -> impl Iterator<Item = (u32, u32)> + 'a {
        let mut walk = BucketWalk::new(bin_hash(bin), self.w);
        (0..self.r as u32).map(move |row| (row, walk.next_bucket() as u32))
    }

    /// Estimated count = min over rows.
    #[inline]
    pub fn query(&self, bin: &[i32]) -> u32 {
        self.query_hashed(bin_hash(bin))
    }

    /// Query by precomputed bin hash.
    #[inline]
    pub fn query_hashed(&self, h: BinHash) -> u32 {
        let mut walk = BucketWalk::new(h, self.w);
        let mut m = u32::MAX;
        let mut base = 0usize;
        for _ in 0..self.r {
            let c = self.counts.get(base + walk.next_bucket());
            m = m.min(c);
            base += self.w;
        }
        m
    }

    /// Batched query: `out[i] = min over rows` for `hashes[i]`. The fused
    /// score executor calls this once per (chain, level) tile so one
    /// sketch stays cache-hot across the whole batch.
    pub fn query_many(&self, hashes: &[BinHash], out: &mut [u32]) {
        debug_assert_eq!(hashes.len(), out.len());
        for (&h, slot) in hashes.iter().zip(out.iter_mut()) {
            *slot = self.query_hashed(h);
        }
    }

    /// Fill a bucket from the reduce output (total count for (row,col)).
    #[inline]
    pub fn set_bucket(&mut self, row: u32, col: u32, count: u32) {
        self.counts.set(row as usize * self.w + col as usize, count);
    }

    /// Build from a reduced dense count block (row-major [r][w]) — the
    /// collectAsMap-equivalent when the map-side combine is dense.
    /// Storage narrows to the smallest width holding the block's maximum.
    pub fn from_counts(r: usize, w: usize, counts: &[u32]) -> Self {
        assert_eq!(counts.len(), r * w);
        let max = counts.iter().copied().max().unwrap_or(0);
        let counts = if max <= u8::MAX as u32 {
            Counters::U8(counts.iter().map(|&c| c as u8).collect())
        } else if max <= u16::MAX as u32 {
            Counters::U16(counts.iter().map(|&c| c as u16).collect())
        } else {
            Counters::U32(counts.to_vec())
        };
        CountMinSketch { r, w, counts }
    }

    /// Bucket counts widened to `u32` (row-major [r][w]) — the artifact
    /// codec's canonical form, independent of the quantization width.
    pub fn counts_u32(&self) -> Vec<u32> {
        (0..self.counts.len()).map(|i| self.counts.get(i)).collect()
    }

    /// Bits per bucket at the current quantization width (8/16/32).
    pub fn storage_bits(&self) -> u32 {
        self.counts.bits()
    }

    /// Add into a bucket (merging partial counts), saturating.
    #[inline]
    pub fn add_bucket(&mut self, row: u32, col: u32, count: u32) {
        let idx = row as usize * self.w + col as usize;
        let v = self.counts.get(idx).saturating_add(count);
        self.counts.set(idx, v);
    }

    /// Query with a sparse *overlay* of absorbed increments on top of the
    /// base counts: min over rows of `base + overlay`. The overlay is
    /// keyed by the row-major bucket index (`row · w + col`, which fits a
    /// `u32` under the shuffle-key packing limits r < 128, w < 2^20).
    /// With an empty overlay this equals [`query`](Self::query) exactly —
    /// the serving front-end's Arc-shared ensemble depends on that
    /// bit-identity. The sum saturates rather than wrapping.
    #[inline]
    pub fn query_overlaid(&self, bin: &[i32], overlay: &HashMap<u32, u32>) -> u32 {
        let mut walk = BucketWalk::new(bin_hash(bin), self.w);
        let mut m = u32::MAX;
        let mut base = 0usize;
        for _ in 0..self.r {
            let idx = base + walk.next_bucket();
            let v = self
                .counts
                .get(idx)
                .saturating_add(overlay.get(&(idx as u32)).copied().unwrap_or(0));
            if v < m {
                m = v;
            }
            base += self.w;
        }
        m
    }

    /// [`query_overlaid`](Self::query_overlaid) with **two** stacked
    /// overlays: min over rows of `base + cur + prev`. This is the
    /// windowed-decay read path — `cur` is the live absorb block and
    /// `prev` the rotated-out previous window — and with `prev` empty it
    /// is bit-identical to the single-overlay query (which with an empty
    /// `cur` is bit-identical to [`query`](Self::query)). Sums saturate.
    #[inline]
    pub fn query_overlaid2(
        &self,
        bin: &[i32],
        cur: &HashMap<u32, u32>,
        prev: &HashMap<u32, u32>,
    ) -> u32 {
        let mut walk = BucketWalk::new(bin_hash(bin), self.w);
        let mut m = u32::MAX;
        let mut base = 0usize;
        for _ in 0..self.r {
            let idx = base + walk.next_bucket();
            let v = self
                .counts
                .get(idx)
                .saturating_add(cur.get(&(idx as u32)).copied().unwrap_or(0))
                .saturating_add(prev.get(&(idx as u32)).copied().unwrap_or(0));
            if v < m {
                m = v;
            }
            base += self.w;
        }
        m
    }

    /// Record one insertion into a sparse overlay *instead of* the base
    /// counts — the serving absorb path, where the trained counts are
    /// shared read-only across shards and each shard owns only its delta.
    /// `query_overlaid` after `overlay_insert` equals `query` after
    /// [`insert`](Self::insert) on an owned copy, bit for bit.
    #[inline]
    pub fn overlay_insert(&self, bin: &[i32], overlay: &mut HashMap<u32, u32>) {
        let mut walk = BucketWalk::new(bin_hash(bin), self.w);
        let mut base = 0usize;
        for _ in 0..self.r {
            let idx = (base + walk.next_bucket()) as u32;
            let slot = overlay.entry(idx).or_insert(0);
            *slot = slot.saturating_add(1);
            base += self.w;
        }
    }

    /// Merge another CMS of identical shape (distributed partial merge),
    /// saturating bucket-wise.
    pub fn merge(&mut self, other: &CountMinSketch) {
        assert_eq!((self.r, self.w), (other.r, other.w));
        for idx in 0..self.r * self.w {
            let v = self.counts.get(idx).saturating_add(other.counts.get(idx));
            self.counts.set(idx, v);
        }
    }

    /// Total insertions (any row sums to it).
    pub fn total(&self) -> u64 {
        (0..self.w).map(|i| self.counts.get(i) as u64).sum()
    }
}

impl SizeOf for CountMinSketch {
    fn size_of(&self) -> usize {
        std::mem::size_of::<Self>() + self.counts.len() * (self.counts.bits() as usize / 8)
    }
}

/// One exponential-decay step on a sparse overlay: floor-halve every
/// count and drop the entries that reach zero. Integer halving keyed off
/// a *logical* clock (the global submit sequence, never wall time) is
/// what keeps the decayed score sequence bit-replayable: applying this
/// at the same submit boundaries always yields the same overlay,
/// regardless of shard count, thread timing, or a kill→resume in
/// between. Dropping zeroed entries keeps the overlay's footprint
/// proportional to what the half-life actually retains.
pub fn decay_halve_overlay(overlay: &mut HashMap<u32, u32>) {
    overlay.retain(|_, c| {
        *c >>= 1;
        *c > 0
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn never_underestimates() {
        let mut cms = CountMinSketch::new(4, 64);
        let mut rng = Rng::new(1);
        let mut truth = std::collections::HashMap::new();
        for _ in 0..2000 {
            let bin = vec![rng.below(30) as i32, rng.below(5) as i32];
            *truth.entry(bin.clone()).or_insert(0u32) += 1;
            cms.insert(&bin);
        }
        for (bin, &c) in &truth {
            assert!(cms.query(bin) >= c, "underestimate for {bin:?}");
        }
    }

    #[test]
    fn exact_when_sparse() {
        // few distinct keys, wide table → min count is exact w.h.p.
        let mut cms = CountMinSketch::new(10, 1000);
        for i in 0..20 {
            for _ in 0..=i {
                cms.insert(&[i as i32]);
            }
        }
        for i in 0..20i32 {
            assert_eq!(cms.query(&[i]), i as u32 + 1);
        }
    }

    #[test]
    fn unseen_bins_query_zero_when_sparse() {
        let mut cms = CountMinSketch::new(10, 1024);
        for i in 0..10i32 {
            cms.insert(&[i]);
        }
        // with 10 keys in 1024 buckets × 10 rows, an unseen key collides in
        // all 10 rows with probability ≈ (10/1024)^10 ≈ 0
        assert_eq!(cms.query(&[999]), 0);
    }

    #[test]
    fn distributed_fill_equals_pointwise() {
        // simulate the flatMap/reduceByKey path and compare to inserts
        let mut direct = CountMinSketch::new(5, 50);
        let mut via_reduce = CountMinSketch::new(5, 50);
        let mut rng = Rng::new(3);
        let mut pairs: std::collections::HashMap<(u32, u32), u32> = Default::default();
        for _ in 0..500 {
            let bin = vec![rng.below(40) as i32, rng.below(40) as i32];
            direct.insert(&bin);
            for rc in via_reduce.all_cols(&bin).collect::<Vec<_>>() {
                *pairs.entry(rc).or_insert(0) += 1;
            }
        }
        for ((row, col), c) in pairs {
            via_reduce.set_bucket(row, col, c);
        }
        assert_eq!(direct, via_reduce);
    }

    /// The serving-absorb contract: inserting into a sparse overlay over
    /// read-only base counts queries bit-identically to inserting into an
    /// owned copy of the counts.
    #[test]
    fn overlay_insert_and_query_match_in_place_mutation() {
        let mut owned = CountMinSketch::new(6, 64);
        let shared = owned.clone(); // the "trained" base, never mutated
        let mut overlay: HashMap<u32, u32> = HashMap::new();
        let mut rng = Rng::new(17);
        let mut bins = Vec::new();
        for _ in 0..400 {
            let bin = vec![rng.below(50) as i32, rng.below(7) as i32];
            owned.insert(&bin);
            shared.overlay_insert(&bin, &mut overlay);
            bins.push(bin);
        }
        for bin in &bins {
            assert_eq!(owned.query(bin), shared.query_overlaid(bin, &overlay));
        }
        // unseen bins agree too, and an empty overlay is a plain query
        assert_eq!(owned.query(&[-7, 99]), shared.query_overlaid(&[-7, 99], &overlay));
        let empty: HashMap<u32, u32> = HashMap::new();
        for bin in bins.iter().take(20) {
            assert_eq!(shared.query(bin), shared.query_overlaid(bin, &empty));
        }
    }

    /// The windowed read path: two stacked overlays sum like one merged
    /// overlay, and an empty `prev` collapses to the single-overlay query
    /// bit-for-bit.
    #[test]
    fn query_overlaid2_stacks_and_degenerates() {
        let cms = CountMinSketch::new(5, 64);
        let mut cur: HashMap<u32, u32> = HashMap::new();
        let mut prev: HashMap<u32, u32> = HashMap::new();
        let mut merged: HashMap<u32, u32> = HashMap::new();
        let mut rng = Rng::new(23);
        let mut bins = Vec::new();
        for i in 0..300 {
            let bin = vec![rng.below(40) as i32, rng.below(5) as i32];
            let target = if i % 3 == 0 { &mut prev } else { &mut cur };
            cms.overlay_insert(&bin, target);
            cms.overlay_insert(&bin, &mut merged);
            bins.push(bin);
        }
        let empty: HashMap<u32, u32> = HashMap::new();
        for bin in &bins {
            assert_eq!(cms.query_overlaid2(bin, &cur, &prev), cms.query_overlaid(bin, &merged));
            assert_eq!(cms.query_overlaid2(bin, &cur, &empty), cms.query_overlaid(bin, &cur));
        }
    }

    /// Floor-halving decay: counts halve exactly, zeroed entries vanish,
    /// and repeated halving drains any overlay to empty.
    #[test]
    fn decay_halve_overlay_floors_and_drops_zeros() {
        let mut overlay: HashMap<u32, u32> =
            [(0u32, 1u32), (3, 2), (9, 7), (40, u32::MAX)].into_iter().collect();
        decay_halve_overlay(&mut overlay);
        assert_eq!(overlay.get(&0), None, "count 1 halves to zero and is dropped");
        assert_eq!(overlay.get(&3), Some(&1));
        assert_eq!(overlay.get(&9), Some(&3));
        assert_eq!(overlay.get(&40), Some(&(u32::MAX >> 1)));
        for _ in 0..32 {
            decay_halve_overlay(&mut overlay);
        }
        assert!(overlay.is_empty(), "repeated half-lives drain the overlay");
    }

    #[test]
    fn merge_adds() {
        let mut a = CountMinSketch::new(2, 8);
        let mut b = CountMinSketch::new(2, 8);
        a.insert(&[1]);
        b.insert(&[1]);
        b.insert(&[2]);
        a.merge(&b);
        assert_eq!(a.query(&[1]), 2);
        assert_eq!(a.total(), 3);
    }

    #[test]
    #[should_panic]
    fn merge_shape_mismatch_panics() {
        let mut a = CountMinSketch::new(2, 8);
        let b = CountMinSketch::new(2, 9);
        a.merge(&b);
    }

    /// Regression for the silent-wrap bug: a bucket at `u32::MAX` must
    /// stay there under insert/add/merge/overlay instead of wrapping to
    /// ~0 and making the hottest bin look like an outlier.
    #[test]
    fn arithmetic_saturates_instead_of_wrapping() {
        let mut cms = CountMinSketch::new(3, 16);
        let bin = [42];
        for (row, col) in cms.all_cols(&bin).collect::<Vec<_>>() {
            cms.set_bucket(row, col, u32::MAX);
        }
        cms.insert(&bin);
        assert_eq!(cms.query(&bin), u32::MAX);
        cms.add_bucket(0, cms.all_cols(&bin).next().unwrap().1, 10);
        assert_eq!(cms.query(&bin), u32::MAX);
        let other = cms.clone();
        cms.merge(&other);
        assert_eq!(cms.query(&bin), u32::MAX);
        // overlay sum saturates too
        let mut overlay = HashMap::new();
        cms.overlay_insert(&bin, &mut overlay);
        assert_eq!(cms.query_overlaid(&bin, &overlay), u32::MAX);
    }

    /// Quantization is unobservable: counts promote u8 → u16 → u32
    /// without losing a single increment.
    #[test]
    fn promotion_preserves_exact_counts() {
        let mut cms = CountMinSketch::new(2, 8);
        assert_eq!(cms.storage_bits(), 8);
        for i in 0..300u32 {
            cms.insert(&[7]);
            assert_eq!(cms.query(&[7]), i + 1);
        }
        assert_eq!(cms.storage_bits(), 16);
        cms.set_bucket(0, 0, 70_000);
        assert_eq!(cms.storage_bits(), 32);
        // the hot bin's count survived both promotions exactly
        assert_eq!(cms.query(&[7]), 300);
    }

    /// `from_counts` narrows to the smallest width holding the block and
    /// still compares equal to (and queries identically to) a sketch
    /// whose storage was forced wide.
    #[test]
    fn from_counts_narrows_and_queries_match_u32() {
        let mut rng = Rng::new(9);
        let counts: Vec<u32> = (0..5 * 64).map(|_| rng.below(200) as u32).collect();
        let narrow = CountMinSketch::from_counts(5, 64, &counts);
        assert_eq!(narrow.storage_bits(), 8);
        let mut wide = CountMinSketch::from_counts(5, 64, &counts);
        wide.set_bucket(0, 0, 100_000); // force u32 storage...
        wide.set_bucket(0, 0, counts[0]); // ...then restore the value
        assert_eq!(wide.storage_bits(), 32);
        assert_eq!(narrow, wide);
        for v in -50..50i32 {
            assert_eq!(narrow.query(&[v, v * 3]), wide.query(&[v, v * 3]));
        }
        assert_eq!(narrow.counts_u32(), counts);
        // quantized residency is smaller than the u32-equivalent
        assert!(narrow.size_of() < wide.size_of());
    }

    #[test]
    fn query_many_matches_pointwise() {
        let mut cms = CountMinSketch::new(4, 128);
        let mut rng = Rng::new(5);
        let bins: Vec<Vec<i32>> =
            (0..200).map(|_| vec![rng.below(60) as i32, rng.below(60) as i32]).collect();
        let hashes: Vec<BinHash> = bins.iter().map(|b| bin_hash(b)).collect();
        cms.insert_many(&hashes);
        let mut out = vec![0u32; hashes.len()];
        cms.query_many(&hashes, &mut out);
        for (bin, &got) in bins.iter().zip(&out) {
            assert_eq!(got, cms.query(bin));
        }
    }
}
