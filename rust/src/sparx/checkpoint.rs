//! Durable absorb-state checkpoints for the §3.5 serving front-end.
//!
//! A served model's *mutable* state — per-shard LRU sketches, absorbed
//! CMS deltas and counters ([`crate::sparx::StreamScorer::snapshot`]) —
//! dies with the process unless it is checkpointed. This module defines
//! the serializable snapshot unit ([`AbsorbSnapshot`]), the merged
//! multi-shard checkpoint ([`AbsorbCheckpoint`]) and its file form: a
//! model artifact (per-block CRCs + provenance manifest, see
//! [`crate::api::artifact`]) whose detector name is
//! [`CHECKPOINT_DETECTOR`], written by `sparx serve --checkpoint-out`
//! and read back by `serve --resume`. From format v3 the absorbed-delta
//! levels travel compressed (first bucket + strictly-increasing gap
//! varints, varint counts); v2 checkpoint files remain readable.
//!
//! Resume contract: restoring a checkpoint into scorers built from the
//! **same model** (fingerprint equality) and the same shard/cache
//! layout continues the stream **bit-identically** — LRU recency order
//! is preserved entry-for-entry, so even eviction timing reproduces.
//! Corrupt, truncated or schema-mismatched checkpoint files fail typed
//! (never panic), like every other artifact read in the crate.

use crate::api::artifact::{block_err, ModelArtifact};
use crate::api::{Result, SparxError};
use crate::util::codec::{CodecResult, Decoder, Encoder};

use super::stream::ServedEnsemble;

/// Detector-name tag that marks an artifact file as an absorb-state
/// checkpoint rather than a fitted model.
pub const CHECKPOINT_DETECTOR: &str = "absorb-state";

/// One scorer's (= one shard's) serialized mutable state.
#[derive(Debug, Clone, PartialEq)]
pub struct AbsorbSnapshot {
    /// δ-updates this scorer processed.
    pub processed: u64,
    /// LRU evictions so far.
    pub evicted: u64,
    /// Points absorbed into the delta overlay.
    pub absorbed: u64,
    /// Cached sketches in **LRU → MRU order** (re-inserting in this
    /// order reproduces the recency order exactly).
    pub entries: Vec<(u64, Vec<f32>)>,
    /// Absorbed CMS increments per (chain-major) level, each sorted by
    /// row-major bucket index.
    pub delta: Vec<Vec<(u32, u32)>>,
}

impl AbsorbSnapshot {
    /// Cache admissions implied by this snapshot (`admitted − evicted ==
    /// resident` is the serving counter invariant).
    pub fn admitted(&self) -> u64 {
        self.evicted + self.entries.len() as u64
    }
}

/// The merged, durable serving state: the header that pins it to one
/// model + shard layout, plus every shard's snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct AbsorbCheckpoint {
    /// `ServedEnsemble::model_fingerprint` of the served model — resume
    /// requires exact equality (bit-identical continuation needs the
    /// exact trained counts).
    pub model_fingerprint: u32,
    /// `ServedEnsemble::schema_fingerprint` of the served model.
    pub schema_fingerprint: u32,
    /// Shard count the state was captured under; resume must match (the
    /// murmur ID route and per-shard LRU orders are S-specific).
    pub shards: u32,
    /// Per-shard LRU capacity at capture time; resume must match
    /// (eviction timing depends on it).
    pub cache_per_shard: u64,
    /// Updates submitted to the sharded scorer when the checkpoint was
    /// cut — the resumed scorer continues its submit sequence here.
    pub submitted: u64,
    /// Whether the capturing run absorbed every update (`--absorb`).
    /// Resume must match: an absorb-mode mismatch silently diverges the
    /// continued stream, so it is rejected typed like shards/cache.
    pub absorb: bool,
    // serving-schema summary, duplicated from the ensemble so mismatch
    // errors can name shapes without loading the model
    pub k: usize,
    pub depth: usize,
    pub num_chains: usize,
    pub cms_rows: usize,
    pub cms_cols: usize,
    /// One snapshot per shard, in shard order.
    pub snapshots: Vec<AbsorbSnapshot>,
}

impl AbsorbCheckpoint {
    /// Header fields derived from the served ensemble; `snapshots` and
    /// `submitted` are filled by the caller.
    pub fn for_ensemble(
        ens: &ServedEnsemble,
        shards: u32,
        cache_per_shard: u64,
        submitted: u64,
        absorb: bool,
        snapshots: Vec<AbsorbSnapshot>,
    ) -> AbsorbCheckpoint {
        AbsorbCheckpoint {
            model_fingerprint: ens.model_fingerprint(),
            schema_fingerprint: ens.schema_fingerprint(),
            shards,
            cache_per_shard,
            submitted,
            absorb,
            k: ens.k(),
            depth: ens.depth(),
            num_chains: ens.num_chains(),
            cms_rows: ens.cms_rows(),
            cms_cols: ens.cms_cols(),
            snapshots,
        }
    }

    /// Typed pre-restore validation against a live ensemble and serve
    /// configuration. Everything that would make the continuation not
    /// bit-identical is rejected here, before any state moves.
    pub fn validate_for(
        &self,
        ens: &ServedEnsemble,
        shards: usize,
        cache_per_shard: usize,
        absorb: bool,
    ) -> Result<()> {
        if self.model_fingerprint != ens.model_fingerprint() {
            return Err(SparxError::InvalidParams(format!(
                "checkpoint was taken against a different model \
                 (fingerprint {:08x}, served model {:08x}) — resume requires the exact \
                 artifact the checkpoint was written under",
                self.model_fingerprint,
                ens.model_fingerprint()
            )));
        }
        if self.shards as usize != shards {
            return Err(SparxError::InvalidParams(format!(
                "checkpoint holds {} shard snapshot(s) but serve is configured with \
                 --shards {shards}; per-shard LRU state only restores onto the same layout",
                self.shards
            )));
        }
        if self.cache_per_shard as usize != cache_per_shard {
            return Err(SparxError::InvalidParams(format!(
                "checkpoint was taken with --cache {} but serve is configured with \
                 --cache {cache_per_shard}; eviction timing depends on the capacity",
                self.cache_per_shard
            )));
        }
        if self.absorb != absorb {
            return Err(SparxError::InvalidParams(format!(
                "checkpoint was taken with absorb mode {} but serve is configured with \
                 absorb mode {}; a mismatch silently diverges the continued stream — \
                 {} --absorb to match",
                if self.absorb { "on" } else { "off" },
                if absorb { "on" } else { "off" },
                if self.absorb { "pass" } else { "drop" }
            )));
        }
        if self.snapshots.len() != shards {
            return Err(SparxError::InvalidParams(format!(
                "checkpoint header declares {} shards but carries {} snapshots",
                self.shards,
                self.snapshots.len()
            )));
        }
        Ok(())
    }

    /// Merge the per-shard snapshots into one aggregate state: entries
    /// concatenated in shard order, deltas summed bucket-wise, counters
    /// summed. Because every ID is pinned to one shard, the merged
    /// sketch set and summed delta equal what a single-shard scorer
    /// would hold for the same stream (in the no-eviction regime) — the
    /// property `rust/tests/checkpoint.rs` asserts for any S.
    pub fn merged(&self) -> AbsorbSnapshot {
        let levels = self.num_chains * self.depth;
        let mut merged = AbsorbSnapshot {
            processed: 0,
            evicted: 0,
            absorbed: 0,
            entries: Vec::new(),
            delta: vec![Vec::new(); levels],
        };
        let mut maps: Vec<std::collections::HashMap<u32, u32>> =
            vec![std::collections::HashMap::new(); levels];
        for snap in &self.snapshots {
            merged.processed += snap.processed;
            merged.evicted += snap.evicted;
            merged.absorbed += snap.absorbed;
            merged.entries.extend(snap.entries.iter().cloned());
            for (map, lvl) in maps.iter_mut().zip(&snap.delta) {
                for &(bucket, count) in lvl {
                    let slot_count = map.entry(bucket).or_insert(0);
                    *slot_count = slot_count.saturating_add(count);
                }
            }
        }
        for (dst, map) in merged.delta.iter_mut().zip(maps) {
            let mut v: Vec<(u32, u32)> = map.into_iter().collect();
            v.sort_unstable();
            *dst = v;
        }
        merged
    }

    // ------------------------------------------------------ file format

    /// Wrap the checkpoint in a current-format artifact container: the
    /// header travels in the params block, the snapshots in the payload,
    /// each with its own CRC. Callers add provenance manifest entries
    /// with [`ModelArtifact::with_manifest`].
    pub fn to_artifact(&self) -> ModelArtifact {
        let mut params = Encoder::new();
        params.put_u32(self.model_fingerprint);
        params.put_u32(self.schema_fingerprint);
        params.put_u32(self.shards);
        params.put_u64(self.cache_per_shard);
        params.put_u64(self.submitted);
        params.put_u8(u8::from(self.absorb));
        params.put_usize(self.k);
        params.put_usize(self.depth);
        params.put_usize(self.num_chains);
        params.put_usize(self.cms_rows);
        params.put_usize(self.cms_cols);
        let mut payload = Encoder::new();
        payload.put_u32(self.snapshots.len() as u32);
        for snap in &self.snapshots {
            encode_snapshot(&mut payload, snap, crate::api::artifact::FORMAT_VERSION);
        }
        ModelArtifact::new(CHECKPOINT_DETECTOR, params.into_bytes(), payload.into_bytes())
    }

    /// Parse an artifact back into a checkpoint, validating internal
    /// consistency (shard/snapshot counts, delta level counts, sketch
    /// widths, bucket ranges). Framing damage surfaces from the artifact
    /// layer as `MissingArtifact`; a well-framed file that is not an
    /// absorb-state checkpoint, or whose blocks are inconsistent, fails
    /// `InvalidParams`.
    pub fn from_artifact(art: &ModelArtifact) -> Result<AbsorbCheckpoint> {
        if art.detector != CHECKPOINT_DETECTOR {
            return Err(SparxError::InvalidParams(format!(
                "expected an absorb-state checkpoint, found a {:?} artifact — \
                 `--resume` takes the file `serve --checkpoint-out` wrote",
                art.detector
            )));
        }
        let blk = |e| block_err(CHECKPOINT_DETECTOR, e);
        let mut dec = Decoder::new(&art.params);
        let header = decode_header(&mut dec).map_err(blk)?;
        dec.finish().map_err(blk)?;
        let mut ckpt = header;
        let mut dec = Decoder::new(&art.payload);
        decode_snapshots(&mut dec, &mut ckpt, art.version).map_err(blk)?;
        dec.finish().map_err(blk)?;
        Ok(ckpt)
    }

    /// Write the checkpoint file — atomically, via the one shared
    /// temp+rename discipline in [`ModelArtifact::save`], so a crash
    /// mid-write can never leave a torn checkpoint where a good one
    /// stood.
    pub fn save(&self, path: &str, manifest: Vec<(String, String)>) -> Result<()> {
        self.to_artifact().with_manifest(manifest).save(path).map(|_| ())
    }

    /// Read and parse a checkpoint file.
    pub fn load(path: &str) -> Result<AbsorbCheckpoint> {
        Self::from_artifact(&ModelArtifact::load(path)?)
    }
}

/// Snapshot wire form. The counters and sketch entries are identical
/// across versions; the delta levels are raw `(u32 bucket, u32 count)`
/// pairs in v2 and — because buckets are strictly increasing and counts
/// are small — `varint(first bucket) + varint(gap)…` with varint counts
/// from v3 on.
fn encode_snapshot(enc: &mut Encoder, snap: &AbsorbSnapshot, version: u16) {
    enc.put_u64(snap.processed);
    enc.put_u64(snap.evicted);
    enc.put_u64(snap.absorbed);
    enc.put_u32(snap.entries.len() as u32);
    for (id, sketch) in &snap.entries {
        enc.put_u64(*id);
        enc.put_f32_slice(sketch);
    }
    enc.put_u32(snap.delta.len() as u32);
    for lvl in &snap.delta {
        enc.put_u32(lvl.len() as u32);
        if version >= 3 {
            let mut prev = 0u32;
            for (i, &(bucket, count)) in lvl.iter().enumerate() {
                let gap = if i == 0 { bucket } else { bucket - prev };
                enc.put_varint(gap as u64);
                enc.put_varint(count as u64);
                prev = bucket;
            }
        } else {
            for &(bucket, count) in lvl {
                enc.put_u32(bucket);
                enc.put_u32(count);
            }
        }
    }
}

fn decode_header(dec: &mut Decoder) -> CodecResult<AbsorbCheckpoint> {
    let ckpt = AbsorbCheckpoint {
        model_fingerprint: dec.u32()?,
        schema_fingerprint: dec.u32()?,
        shards: dec.u32()?,
        cache_per_shard: dec.u64()?,
        submitted: dec.u64()?,
        absorb: match dec.u8()? {
            0 => false,
            1 => true,
            other => return Err(format!("unknown absorb-mode tag {other}")),
        },
        k: dec.usize()?,
        depth: dec.usize()?,
        num_chains: dec.usize()?,
        cms_rows: dec.usize()?,
        cms_cols: dec.usize()?,
        snapshots: Vec::new(),
    };
    if ckpt.shards == 0 || ckpt.shards > 4096 {
        return Err(format!("checkpoint shard count {} out of range", ckpt.shards));
    }
    // the LRU pre-reserves its declared capacity, so an unbounded value
    // here is a thin-air allocation like the shape fields below
    if ckpt.cache_per_shard == 0 || ckpt.cache_per_shard > (1 << 24) {
        return Err(format!(
            "checkpoint cache capacity {} out of range (1..=2^24)",
            ckpt.cache_per_shard
        ));
    }
    if ckpt.k == 0
        || ckpt.depth == 0
        || ckpt.num_chains == 0
        || ckpt.cms_rows == 0
        || ckpt.cms_cols == 0
    {
        return Err(format!(
            "degenerate checkpoint schema: K={} L={} M={} r={} w={}",
            ckpt.k, ckpt.depth, ckpt.num_chains, ckpt.cms_rows, ckpt.cms_cols
        ));
    }
    // same packing bound the CMS itself enforces; keeps bucket indices
    // in u32 and blocks thin-air allocations from hostile headers
    if ckpt.cms_rows >= 128 || ckpt.cms_cols >= (1 << 20) || ckpt.k > (1 << 24) {
        return Err("checkpoint schema exceeds the serving shape caps".into());
    }
    // ensemble-shape caps: M and L are unbounded in SparxParams, but a
    // checkpoint header declaring absurd values exists only to demand
    // absurd allocations — reject before anything is reserved
    if ckpt.num_chains > (1 << 12) || ckpt.depth > (1 << 12) {
        return Err(format!(
            "checkpoint ensemble shape M={} L={} exceeds the serving shape caps",
            ckpt.num_chains, ckpt.depth
        ));
    }
    Ok(ckpt)
}

fn decode_snapshots(
    dec: &mut Decoder,
    ckpt: &mut AbsorbCheckpoint,
    version: u16,
) -> CodecResult<()> {
    let n = dec.u32()? as usize;
    if n != ckpt.shards as usize {
        return Err(format!(
            "payload carries {n} snapshots but the header declares {} shards",
            ckpt.shards
        ));
    }
    let levels = ckpt.num_chains * ckpt.depth;
    let buckets = (ckpt.cms_rows * ckpt.cms_cols) as u32;
    ckpt.snapshots.reserve(n);
    for _ in 0..n {
        let processed = dec.u64()?;
        let evicted = dec.u64()?;
        let absorbed = dec.u64()?;
        let n_entries = dec.u32()? as usize;
        if n_entries as u64 > ckpt.cache_per_shard {
            return Err(format!(
                "snapshot holds {n_entries} sketches, over the declared cache \
                 capacity {}",
                ckpt.cache_per_shard
            ));
        }
        // every entry costs ≥ 12 bytes on the wire; reject declared
        // counts the remaining bytes cannot possibly back
        if dec.remaining() < n_entries.saturating_mul(12) {
            return Err(format!("truncated snapshot: {n_entries} sketch entries declared"));
        }
        let mut entries = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let id = dec.u64()?;
            let sketch = dec.f32_vec()?;
            if sketch.len() != ckpt.k {
                return Err(format!(
                    "sketch for id {id} is {}-wide, header declares K={}",
                    sketch.len(),
                    ckpt.k
                ));
            }
            entries.push((id, sketch));
        }
        let n_levels = dec.u32()? as usize;
        if n_levels != levels {
            return Err(format!(
                "snapshot has {n_levels} delta levels, header declares M·L = {levels}"
            ));
        }
        // every level costs ≥ 4 bytes on the wire; reject declared
        // counts the remaining bytes cannot possibly back (no
        // allocate-then-discover-truncation)
        if dec.remaining() < n_levels.saturating_mul(4) {
            return Err(format!("truncated snapshot: {n_levels} delta levels declared"));
        }
        let mut delta = Vec::with_capacity(n_levels);
        // v2 pairs are 8 raw bytes; v3 pairs are ≥ 2 varint bytes
        let min_pair_bytes: usize = if version >= 3 { 2 } else { 8 };
        for _ in 0..n_levels {
            let n_pairs = dec.u32()? as usize;
            if dec.remaining() < n_pairs.saturating_mul(min_pair_bytes) {
                return Err(format!("truncated snapshot: {n_pairs} delta pairs declared"));
            }
            let mut lvl = Vec::with_capacity(n_pairs);
            let mut prev: Option<u32> = None;
            for _ in 0..n_pairs {
                let (bucket, count) = if version >= 3 {
                    let gap = dec.varint()?;
                    let count = dec.varint()?;
                    if count == 0 || count > u32::MAX as u64 {
                        return Err(format!("delta count {count} out of range"));
                    }
                    if prev.is_some() && gap == 0 {
                        return Err("delta buckets must be strictly increasing".into());
                    }
                    let bucket = prev.map_or(0, u64::from) + gap;
                    if bucket >= buckets as u64 {
                        return Err(format!(
                            "delta bucket {bucket} out of range for a {}×{} CMS",
                            ckpt.cms_rows, ckpt.cms_cols
                        ));
                    }
                    (bucket as u32, count as u32)
                } else {
                    let bucket = dec.u32()?;
                    let count = dec.u32()?;
                    if bucket >= buckets {
                        return Err(format!(
                            "delta bucket {bucket} out of range for a {}×{} CMS",
                            ckpt.cms_rows, ckpt.cms_cols
                        ));
                    }
                    if count == 0 {
                        return Err("delta entries must carry a non-zero count".into());
                    }
                    if let Some(p) = prev {
                        if bucket <= p {
                            return Err("delta buckets must be strictly increasing".into());
                        }
                    }
                    (bucket, count)
                };
                prev = Some(bucket);
                lvl.push((bucket, count));
            }
            delta.push(lvl);
        }
        ckpt.snapshots.push(AbsorbSnapshot { processed, evicted, absorbed, entries, delta });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AbsorbCheckpoint {
        AbsorbCheckpoint {
            model_fingerprint: 0xDEAD_BEEF,
            schema_fingerprint: 0x5A5A_0001,
            shards: 2,
            cache_per_shard: 4,
            submitted: 17,
            absorb: true,
            k: 3,
            depth: 2,
            num_chains: 2,
            cms_rows: 4,
            cms_cols: 16,
            snapshots: vec![
                AbsorbSnapshot {
                    processed: 10,
                    evicted: 1,
                    absorbed: 3,
                    entries: vec![(7, vec![1.0, -2.0, 0.5]), (9, vec![0.0, 0.0, 4.0])],
                    delta: vec![vec![(0, 2), (5, 1)], vec![], vec![(63, 4)], vec![]],
                },
                AbsorbSnapshot {
                    processed: 7,
                    evicted: 0,
                    absorbed: 0,
                    entries: vec![(2, vec![0.25, 0.0, -0.0])],
                    delta: vec![vec![], vec![], vec![], vec![]],
                },
            ],
        }
    }

    #[test]
    fn artifact_round_trip_is_exact() {
        let ckpt = sample();
        let art = ckpt.to_artifact();
        assert_eq!(art.detector, CHECKPOINT_DETECTOR);
        let back = AbsorbCheckpoint::from_artifact(
            &ModelArtifact::from_bytes(&art.to_bytes()).unwrap(),
        )
        .unwrap();
        assert_eq!(ckpt, back);
    }

    #[test]
    fn non_checkpoint_artifacts_are_rejected_typed() {
        let art = ModelArtifact::new("sparx", vec![1, 2], vec![3]);
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&art),
            Err(SparxError::InvalidParams(_))
        ));
    }

    #[test]
    fn inconsistent_blocks_fail_typed() {
        let ckpt = sample();
        // header/payload snapshot-count mismatch
        let mut short = ckpt.clone();
        short.snapshots.pop();
        let art = short.to_artifact();
        // keep the header claiming 2 shards but ship 1 snapshot
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&art),
            Err(SparxError::InvalidParams(_))
        ));
        // wrong sketch width
        let mut bad = ckpt.clone();
        bad.snapshots[0].entries[0].1.push(9.0);
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
        // bucket out of range
        let mut bad = ckpt.clone();
        bad.snapshots[0].delta[0].push((4 * 16, 1));
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
        // over-capacity snapshot
        let mut bad = ckpt;
        for id in 100..110 {
            bad.snapshots[0].entries.push((id, vec![0.0; 3]));
        }
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
    }

    /// Checkpoint files written by the previous release (format v2, raw
    /// delta pairs) still restore exactly; the v3 payload for the same
    /// state is smaller.
    #[test]
    fn v2_checkpoint_payloads_still_decode() {
        let ckpt = sample();
        let mut art = ckpt.to_artifact();
        let v3_payload_len = art.payload.len();
        // rebuild the payload in the v2 (raw pairs) layout, mark the file v2
        let mut payload = Encoder::new();
        payload.put_u32(ckpt.snapshots.len() as u32);
        for snap in &ckpt.snapshots {
            encode_snapshot(&mut payload, snap, 2);
        }
        art.payload = payload.into_bytes();
        art.version = 2;
        assert!(v3_payload_len < art.payload.len(), "v3 must compress the delta levels");
        let reread = ModelArtifact::from_bytes(&art.to_bytes()).unwrap();
        let back = AbsorbCheckpoint::from_artifact(&reread).unwrap();
        assert_eq!(ckpt, back);
    }

    #[test]
    fn merged_sums_counters_and_deltas() {
        let ckpt = sample();
        let merged = ckpt.merged();
        assert_eq!(merged.processed, 17);
        assert_eq!(merged.evicted, 1);
        assert_eq!(merged.absorbed, 3);
        assert_eq!(merged.entries.len(), 3);
        assert_eq!(merged.delta[0], vec![(0, 2), (5, 1)]);
        assert_eq!(merged.delta[2], vec![(63, 4)]);
        assert_eq!(merged.admitted(), 1 + 3);
    }
}
