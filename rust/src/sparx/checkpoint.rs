//! Durable absorb-state checkpoints for the §3.5 serving front-end.
//!
//! A served model's *mutable* state — resident sketches, absorbed CMS
//! overlays and counters — dies with the process unless it is
//! checkpointed. This module defines the per-scorer snapshot unit
//! ([`AbsorbSnapshot`], what [`crate::sparx::StreamScorer::snapshot`]
//! produces) and the durable checkpoint ([`AbsorbCheckpoint`]) plus its
//! file form: a model artifact (per-block CRCs + provenance manifest,
//! see [`crate::api::artifact`]) whose detector name is
//! [`CHECKPOINT_DETECTOR`], written by `sparx serve --checkpoint-out`
//! and read back by `serve --resume`.
//!
//! ## Format v4: shard-layout-independent state
//!
//! Up to format v3 a checkpoint was a vector of per-shard snapshots and
//! resume demanded the identical `--shards`/`--cache` layout. From v4
//! the checkpoint stores *global* state instead:
//!
//! * every resident sketch tagged with the submit sequence of its last
//!   touch, in global LRU → MRU order (the serving pool's eviction
//!   directory order — S-independent by construction);
//! * one merged **visible** CMS overlay (published absorb epochs; every
//!   shard holds the identical copy, so one travels);
//! * one merged **pending** overlay (absorbed since the last epoch
//!   merge — a mid-epoch checkpoint must *not* flush visibility, or the
//!   resumed scores would diverge from the uninterrupted run).
//!
//! Because nothing in the payload depends on the shard count, `serve
//! --resume` may change `--shards` (and `--cache`) freely: the entries
//! are re-partitioned by `shard_of(id, S_new)` and recency is rebuilt
//! from the sequence tags. v2/v3 checkpoint files remain readable and
//! are converted on load (their per-shard recency interleaving was
//! never recorded, so conversion synthesizes tags in shard order — a
//! valid recency, though not bit-continuous with the pre-v4 run).
//!
//! Resume contract: restoring a v4 checkpoint into a pool built from
//! the **same model** (fingerprint equality) and absorb mode continues
//! the stream **bit-identically at any shard count** — recency order is
//! preserved entry-for-entry, so even eviction timing reproduces.
//! Corrupt, truncated or schema-mismatched checkpoint files fail typed
//! (never panic), like every other artifact read in the crate.
//!
//! ## Format v5: decay schedules, the `prev` window block, named queries
//!
//! v5 appends the time-decay serving state (see [`super::decay`]):
//!
//! * the capture-time `half_life` / `window` schedule (params block) —
//!   resume must run the same schedule or the continued scores diverge,
//!   so a mismatch is rejected typed like an absorb-mode mismatch;
//! * the rotated **previous window** overlay (all-empty until the first
//!   rotation);
//! * every registered named query: its schedule, `scored` counter and
//!   both overlay blocks.
//!
//! v4 files (and converted v≤3 ones) load with the decay state
//! defaulted — no schedule, empty `prev`, no queries.

use crate::api::artifact::{block_err, ModelArtifact};
use crate::api::{Result, SparxError};
use crate::util::codec::{CodecResult, Decoder, Encoder};

use super::decay::{DecaySpec, MAX_QUERIES, MAX_QUERY_NAME};
use super::stream::ServedEnsemble;

/// Detector-name tag that marks an artifact file as an absorb-state
/// checkpoint rather than a fitted model.
pub const CHECKPOINT_DETECTOR: &str = "absorb-state";

/// One scorer's serialized mutable state (the snapshot/restore unit of
/// [`crate::sparx::StreamScorer`]; also the legacy v≤3 payload element).
#[derive(Debug, Clone, PartialEq)]
pub struct AbsorbSnapshot {
    /// δ-updates this scorer processed.
    pub processed: u64,
    /// LRU evictions so far.
    pub evicted: u64,
    /// Points absorbed into the delta overlay.
    pub absorbed: u64,
    /// Cached sketches in **LRU → MRU order** (re-inserting in this
    /// order reproduces the recency order exactly).
    pub entries: Vec<(u64, Vec<f32>)>,
    /// Absorbed CMS increments per (chain-major) level, each sorted by
    /// row-major bucket index.
    pub delta: Vec<Vec<(u32, u32)>>,
}

impl AbsorbSnapshot {
    /// Cache admissions implied by this snapshot (`admitted − evicted ==
    /// resident` is the serving counter invariant).
    pub fn admitted(&self) -> u64 {
        self.evicted + self.entries.len() as u64
    }
}

/// One named query's persisted state (v5 payload element) — the durable
/// form of [`super::decay::QueryState`].
#[derive(Debug, Clone, PartialEq)]
pub struct QueryRecord {
    pub name: String,
    pub half_life: u64,
    pub window: u64,
    /// Named-score probes served so far.
    pub scored: u64,
    /// Live block, per chain-major level, sorted by bucket.
    pub cur: Vec<Vec<(u32, u32)>>,
    /// Previous window block, same layout.
    pub prev: Vec<Vec<(u32, u32)>>,
}

/// The durable serving state (format v5; v4 loads with decay state
/// defaulted): pinned to one model by fingerprint, independent of the
/// shard layout by construction.
#[derive(Debug, Clone, PartialEq)]
pub struct AbsorbCheckpoint {
    /// `ServedEnsemble::model_fingerprint` of the served model — resume
    /// requires exact equality (bit-identical continuation needs the
    /// exact trained counts).
    pub model_fingerprint: u32,
    /// `ServedEnsemble::schema_fingerprint` of the served model.
    pub schema_fingerprint: u32,
    /// Shard count the state was captured under. Informational from v4
    /// on (`serve --resume` may pick any shard count); kept so `--resume`
    /// can default to the capture-time parallelism.
    pub shards: u32,
    /// **Total** resident-sketch budget (the global eviction directory's
    /// capacity) at capture time. Resume adopts it unless `--cache`
    /// overrides.
    pub cache_total: u64,
    /// Updates submitted to the serving pool when the checkpoint was
    /// cut — the resumed pool continues its submit sequence here.
    pub submitted: u64,
    /// Whether the capturing run absorbed every update (`--absorb`).
    /// Resume must match: an absorb-mode mismatch silently diverges the
    /// continued stream, so it is rejected typed.
    pub absorb: bool,
    /// The capture-time `--half-life` period (0 = off). Resume adopts it
    /// when unflagged; an explicit mismatch is rejected typed, like an
    /// absorb-mode mismatch.
    pub half_life: u64,
    /// The capture-time `--window` period (0 = off); same resume rules.
    pub window: u64,
    // serving-schema summary, duplicated from the ensemble so mismatch
    // errors can name shapes without loading the model
    pub k: usize,
    pub depth: usize,
    pub num_chains: usize,
    pub cms_rows: usize,
    pub cms_cols: usize,
    /// Aggregate counters across the whole pool.
    pub processed: u64,
    pub evicted: u64,
    pub absorbed: u64,
    /// Resident sketches in **global LRU → MRU order**, each tagged with
    /// the submit sequence of its last touch (strictly increasing along
    /// the vector — recency order *is* last-touch order).
    pub entries: Vec<(u64, u64, Vec<f32>)>,
    /// The published (visible) CMS overlay, per chain-major level,
    /// sorted by bucket.
    pub visible: Vec<Vec<(u32, u32)>>,
    /// Absorbed-but-unpublished increments (mid-epoch state), merged
    /// across shards, per chain-major level, sorted by bucket.
    pub pending: Vec<Vec<(u32, u32)>>,
    /// The rotated previous-window overlay (empty for v≤4 files and
    /// until the first rotation), per chain-major level.
    pub prev_visible: Vec<Vec<(u32, u32)>>,
    /// Registered named queries, in registration order (empty for v≤4
    /// files).
    pub queries: Vec<QueryRecord>,
}

impl AbsorbCheckpoint {
    /// Header fields derived from the served ensemble; counters,
    /// `entries` and the overlays are filled by the caller.
    pub fn for_ensemble(
        ens: &ServedEnsemble,
        shards: u32,
        cache_total: u64,
        submitted: u64,
        absorb: bool,
        decay: DecaySpec,
    ) -> AbsorbCheckpoint {
        AbsorbCheckpoint {
            model_fingerprint: ens.model_fingerprint(),
            schema_fingerprint: ens.schema_fingerprint(),
            shards,
            cache_total,
            submitted,
            absorb,
            half_life: decay.half_life,
            window: decay.window,
            k: ens.k(),
            depth: ens.depth(),
            num_chains: ens.num_chains(),
            cms_rows: ens.cms_rows(),
            cms_cols: ens.cms_cols(),
            processed: 0,
            evicted: 0,
            absorbed: 0,
            entries: Vec::new(),
            visible: Vec::new(),
            pending: Vec::new(),
            prev_visible: Vec::new(),
            queries: Vec::new(),
        }
    }

    /// Cache admissions implied by this checkpoint.
    pub fn admitted(&self) -> u64 {
        self.evicted + self.entries.len() as u64
    }

    /// The capture-time decay schedule (what unflagged resume adopts).
    pub fn decay(&self) -> DecaySpec {
        DecaySpec::new(self.half_life, self.window)
    }

    /// Typed pre-restore validation against a live ensemble and serve
    /// configuration. From v4 on only what genuinely breaks bit-identity
    /// is checked: the model fingerprint, the absorb mode and (v5) the
    /// decay schedule. Shard count and cache budget may change freely on
    /// resume.
    pub fn validate_for(&self, ens: &ServedEnsemble, absorb: bool, decay: DecaySpec) -> Result<()> {
        if self.model_fingerprint != ens.model_fingerprint() {
            return Err(SparxError::InvalidParams(format!(
                "checkpoint was taken against a different model \
                 (fingerprint {:08x}, served model {:08x}) — resume requires the exact \
                 artifact the checkpoint was written under",
                self.model_fingerprint,
                ens.model_fingerprint()
            )));
        }
        if self.absorb != absorb {
            return Err(SparxError::InvalidParams(format!(
                "checkpoint was taken with absorb mode {} but serve is configured with \
                 absorb mode {}; a mismatch silently diverges the continued stream — \
                 {} --absorb to match",
                if self.absorb { "on" } else { "off" },
                if absorb { "on" } else { "off" },
                if self.absorb { "pass" } else { "drop" }
            )));
        }
        if self.decay() != decay {
            return Err(SparxError::InvalidParams(format!(
                "checkpoint was taken with half-life {} / window {} but serve is configured \
                 with half-life {} / window {}; a schedule mismatch silently diverges the \
                 continued stream — omit the flags to adopt the checkpoint's schedule",
                self.half_life, self.window, decay.half_life, decay.window
            )));
        }
        Ok(())
    }

    // ------------------------------------------------------ file format

    /// Wrap the checkpoint in a current-format artifact container: the
    /// header travels in the params block, the entries + overlays in
    /// the payload, each with its own CRC. Callers add provenance
    /// manifest entries with [`ModelArtifact::with_manifest`].
    pub fn to_artifact(&self) -> ModelArtifact {
        let mut params = Encoder::new();
        params.put_u32(self.model_fingerprint);
        params.put_u32(self.schema_fingerprint);
        params.put_u32(self.shards);
        params.put_u64(self.cache_total);
        params.put_u64(self.submitted);
        params.put_u8(u8::from(self.absorb));
        params.put_usize(self.k);
        params.put_usize(self.depth);
        params.put_usize(self.num_chains);
        params.put_usize(self.cms_rows);
        params.put_usize(self.cms_cols);
        params.put_u64(self.processed);
        params.put_u64(self.evicted);
        params.put_u64(self.absorbed);
        // v5 params tail: the decay schedule
        params.put_u64(self.half_life);
        params.put_u64(self.window);
        let mut payload = Encoder::new();
        payload.put_u32(self.entries.len() as u32);
        for (id, seq, sketch) in &self.entries {
            payload.put_u64(*id);
            payload.put_u64(*seq);
            payload.put_f32_slice(sketch);
        }
        encode_levels(&mut payload, &self.visible);
        encode_levels(&mut payload, &self.pending);
        // v5 payload tail: the prev window block + the named queries
        encode_levels(&mut payload, &self.prev_visible);
        payload.put_u32(self.queries.len() as u32);
        for q in &self.queries {
            payload.put_str(&q.name);
            payload.put_u64(q.half_life);
            payload.put_u64(q.window);
            payload.put_u64(q.scored);
            encode_levels(&mut payload, &q.cur);
            encode_levels(&mut payload, &q.prev);
        }
        ModelArtifact::new(CHECKPOINT_DETECTOR, params.into_bytes(), payload.into_bytes())
    }

    /// Parse an artifact back into a checkpoint, validating internal
    /// consistency (entry counts vs the cache budget, recency-tag
    /// monotonicity, delta level counts, sketch widths, bucket ranges).
    /// v2/v3 files decode through the legacy per-shard layout and are
    /// converted (see the module docs). Framing damage surfaces from
    /// the artifact layer as `MissingArtifact`; a well-framed file that
    /// is not an absorb-state checkpoint, or whose blocks are
    /// inconsistent, fails `InvalidParams`.
    pub fn from_artifact(art: &ModelArtifact) -> Result<AbsorbCheckpoint> {
        if art.detector != CHECKPOINT_DETECTOR {
            return Err(SparxError::InvalidParams(format!(
                "expected an absorb-state checkpoint, found a {:?} artifact — \
                 `--resume` takes the file `serve --checkpoint-out` wrote",
                art.detector
            )));
        }
        let blk = |e| block_err(CHECKPOINT_DETECTOR, e);
        if art.version < 4 {
            let mut dec = Decoder::new(&art.params);
            let (ckpt, cache_per_shard) = decode_header_legacy(&mut dec).map_err(blk)?;
            dec.finish().map_err(blk)?;
            let mut dec = Decoder::new(&art.payload);
            let snapshots =
                decode_snapshots_legacy(&mut dec, &ckpt, cache_per_shard, art.version)
                    .map_err(blk)?;
            dec.finish().map_err(blk)?;
            return Ok(convert_legacy(ckpt, snapshots));
        }
        let mut dec = Decoder::new(&art.params);
        let mut ckpt = decode_header_v4(&mut dec, art.version).map_err(blk)?;
        dec.finish().map_err(blk)?;
        let mut dec = Decoder::new(&art.payload);
        decode_payload_v4(&mut dec, &mut ckpt, art.version).map_err(blk)?;
        dec.finish().map_err(blk)?;
        Ok(ckpt)
    }

    /// The provenance manifest a checkpoint file carries (carried
    /// verbatim, never interpreted by the loaders) — shared by the CLI
    /// writer and the serving plane's `CHECKPOINT` verb so the two
    /// paths stay indistinguishable on disk.
    pub fn manifest_for(&self, model_path: &str) -> Vec<(String, String)> {
        vec![
            ("kind".into(), "absorb-state checkpoint".into()),
            ("model".into(), model_path.into()),
            ("model-fingerprint".into(), format!("{:08x}", self.model_fingerprint)),
            ("submitted".into(), self.submitted.to_string()),
            ("shards".into(), self.shards.to_string()),
            ("cache-total".into(), self.cache_total.to_string()),
            ("absorb".into(), self.absorb.to_string()),
        ]
    }

    /// Write the checkpoint file — atomically, via the one shared
    /// temp+rename discipline in [`ModelArtifact::save`], so a crash
    /// mid-write can never leave a torn checkpoint where a good one
    /// stood.
    pub fn save(&self, path: &str, manifest: Vec<(String, String)>) -> Result<()> {
        self.to_artifact().with_manifest(manifest).save(path).map(|_| ())
    }

    /// Read and parse a checkpoint file.
    pub fn load(path: &str) -> Result<AbsorbCheckpoint> {
        Self::from_artifact(&ModelArtifact::load(path)?)
    }
}

/// Overlay-levels wire form (v3+ delta codec): `u32` level count, then
/// per level a varint pair count followed by `varint(first bucket) +
/// varint(gap)…` with varint counts (buckets strictly increase, counts
/// are non-zero).
fn encode_levels(enc: &mut Encoder, levels: &[Vec<(u32, u32)>]) {
    enc.put_u32(levels.len() as u32);
    for lvl in levels {
        enc.put_u32(lvl.len() as u32);
        let mut prev = 0u32;
        for (i, &(bucket, count)) in lvl.iter().enumerate() {
            let gap = if i == 0 { bucket } else { bucket - prev };
            enc.put_varint(gap as u64);
            enc.put_varint(count as u64);
            prev = bucket;
        }
    }
}

/// Decode one overlay (level vector), validating level count, bucket
/// range/order and non-zero counts. `version` picks the pair codec
/// (raw `u32` pairs before v3, gap varints from v3 on).
fn decode_levels(
    dec: &mut Decoder,
    want_levels: usize,
    buckets: u32,
    cms_rows: usize,
    cms_cols: usize,
    version: u16,
) -> CodecResult<Vec<Vec<(u32, u32)>>> {
    let n_levels = dec.u32()? as usize;
    if n_levels != want_levels {
        return Err(format!(
            "overlay has {n_levels} delta levels, header declares M·L = {want_levels}"
        ));
    }
    // every level costs ≥ 4 bytes on the wire; reject declared counts
    // the remaining bytes cannot possibly back (no
    // allocate-then-discover-truncation)
    if dec.remaining() < n_levels.saturating_mul(4) {
        return Err(format!("truncated checkpoint: {n_levels} delta levels declared"));
    }
    let mut out = Vec::with_capacity(n_levels);
    // v2 pairs are 8 raw bytes; v3+ pairs are ≥ 2 varint bytes
    let min_pair_bytes: usize = if version >= 3 { 2 } else { 8 };
    for _ in 0..n_levels {
        let n_pairs = dec.u32()? as usize;
        if dec.remaining() < n_pairs.saturating_mul(min_pair_bytes) {
            return Err(format!("truncated checkpoint: {n_pairs} delta pairs declared"));
        }
        let mut lvl = Vec::with_capacity(n_pairs);
        let mut prev: Option<u32> = None;
        for _ in 0..n_pairs {
            let (bucket, count) = if version >= 3 {
                let gap = dec.varint()?;
                let count = dec.varint()?;
                if count == 0 || count > u32::MAX as u64 {
                    return Err(format!("delta count {count} out of range"));
                }
                if prev.is_some() && gap == 0 {
                    return Err("delta buckets must be strictly increasing".into());
                }
                let bucket = prev.map_or(0, u64::from) + gap;
                if bucket >= buckets as u64 {
                    return Err(format!(
                        "delta bucket {bucket} out of range for a {cms_rows}×{cms_cols} CMS"
                    ));
                }
                (bucket as u32, count as u32)
            } else {
                let bucket = dec.u32()?;
                let count = dec.u32()?;
                if bucket >= buckets {
                    return Err(format!(
                        "delta bucket {bucket} out of range for a {cms_rows}×{cms_cols} CMS"
                    ));
                }
                if count == 0 {
                    return Err("delta entries must carry a non-zero count".into());
                }
                if let Some(p) = prev {
                    if bucket <= p {
                        return Err("delta buckets must be strictly increasing".into());
                    }
                }
                (bucket, count)
            };
            prev = Some(bucket);
            lvl.push((bucket, count));
        }
        out.push(lvl);
    }
    Ok(out)
}

/// Shared schema-shape validation for both header layouts.
fn check_shape(ckpt: &AbsorbCheckpoint) -> CodecResult<()> {
    if ckpt.shards == 0 || ckpt.shards > 4096 {
        return Err(format!("checkpoint shard count {} out of range", ckpt.shards));
    }
    if ckpt.k == 0
        || ckpt.depth == 0
        || ckpt.num_chains == 0
        || ckpt.cms_rows == 0
        || ckpt.cms_cols == 0
    {
        return Err(format!(
            "degenerate checkpoint schema: K={} L={} M={} r={} w={}",
            ckpt.k, ckpt.depth, ckpt.num_chains, ckpt.cms_rows, ckpt.cms_cols
        ));
    }
    // same packing bound the CMS itself enforces; keeps bucket indices
    // in u32 and blocks thin-air allocations from hostile headers
    if ckpt.cms_rows >= 128 || ckpt.cms_cols >= (1 << 20) || ckpt.k > (1 << 24) {
        return Err("checkpoint schema exceeds the serving shape caps".into());
    }
    // ensemble-shape caps: M and L are unbounded in SparxParams, but a
    // checkpoint header declaring absurd values exists only to demand
    // absurd allocations — reject before anything is reserved
    if ckpt.num_chains > (1 << 12) || ckpt.depth > (1 << 12) {
        return Err(format!(
            "checkpoint ensemble shape M={} L={} exceeds the serving shape caps",
            ckpt.num_chains, ckpt.depth
        ));
    }
    Ok(())
}

fn decode_header_v4(dec: &mut Decoder, version: u16) -> CodecResult<AbsorbCheckpoint> {
    let mut ckpt = AbsorbCheckpoint {
        model_fingerprint: dec.u32()?,
        schema_fingerprint: dec.u32()?,
        shards: dec.u32()?,
        cache_total: dec.u64()?,
        submitted: dec.u64()?,
        absorb: match dec.u8()? {
            0 => false,
            1 => true,
            other => return Err(format!("unknown absorb-mode tag {other}")),
        },
        half_life: 0,
        window: 0,
        k: dec.usize()?,
        depth: dec.usize()?,
        num_chains: dec.usize()?,
        cms_rows: dec.usize()?,
        cms_cols: dec.usize()?,
        processed: 0,
        evicted: 0,
        absorbed: 0,
        entries: Vec::new(),
        visible: Vec::new(),
        pending: Vec::new(),
        prev_visible: Vec::new(),
        queries: Vec::new(),
    };
    ckpt.processed = dec.u64()?;
    ckpt.evicted = dec.u64()?;
    ckpt.absorbed = dec.u64()?;
    if version >= 5 {
        ckpt.half_life = dec.u64()?;
        ckpt.window = dec.u64()?;
        if ckpt.half_life > 0 && !ckpt.absorb {
            return Err("checkpoint declares a half-life without absorb mode".into());
        }
        if ckpt.window > 0 && !ckpt.absorb {
            return Err("checkpoint declares a window without absorb mode".into());
        }
    }
    // the resume path pre-reserves the directory's declared capacity,
    // so an unbounded value here is a thin-air allocation like the
    // shape fields
    if ckpt.cache_total == 0 || ckpt.cache_total > (1 << 24) {
        return Err(format!(
            "checkpoint cache budget {} out of range (1..=2^24)",
            ckpt.cache_total
        ));
    }
    check_shape(&ckpt)?;
    Ok(ckpt)
}

fn decode_payload_v4(
    dec: &mut Decoder,
    ckpt: &mut AbsorbCheckpoint,
    version: u16,
) -> CodecResult<()> {
    let n_entries = dec.u32()? as usize;
    if n_entries as u64 > ckpt.cache_total {
        return Err(format!(
            "checkpoint holds {n_entries} sketches, over the declared cache budget {}",
            ckpt.cache_total
        ));
    }
    // every entry costs ≥ 20 bytes on the wire (id + seq + sketch len)
    if dec.remaining() < n_entries.saturating_mul(20) {
        return Err(format!("truncated checkpoint: {n_entries} sketch entries declared"));
    }
    let mut entries = Vec::with_capacity(n_entries);
    let mut prev_seq: Option<u64> = None;
    for _ in 0..n_entries {
        let id = dec.u64()?;
        let seq = dec.u64()?;
        let sketch = dec.f32_vec()?;
        if sketch.len() != ckpt.k {
            return Err(format!(
                "sketch for id {id} is {}-wide, header declares K={}",
                sketch.len(),
                ckpt.k
            ));
        }
        if seq >= ckpt.submitted {
            return Err(format!(
                "entry recency tag {seq} is not before the submit watermark {}",
                ckpt.submitted
            ));
        }
        if let Some(p) = prev_seq {
            if seq <= p {
                return Err(
                    "entry recency tags must strictly increase in LRU→MRU order".into()
                );
            }
        }
        prev_seq = Some(seq);
        entries.push((id, seq, sketch));
    }
    ckpt.entries = entries;
    let levels = ckpt.num_chains * ckpt.depth;
    let buckets = (ckpt.cms_rows * ckpt.cms_cols) as u32;
    ckpt.visible = decode_levels(dec, levels, buckets, ckpt.cms_rows, ckpt.cms_cols, 4)?;
    ckpt.pending = decode_levels(dec, levels, buckets, ckpt.cms_rows, ckpt.cms_cols, 4)?;
    if version < 5 {
        // pre-decay files: no prev block ever rotated, no queries —
        // normalize to the canonical all-empty M·L shape
        ckpt.prev_visible = vec![Vec::new(); levels];
        return Ok(());
    }
    ckpt.prev_visible = decode_levels(dec, levels, buckets, ckpt.cms_rows, ckpt.cms_cols, 5)?;
    let n_queries = dec.u32()? as usize;
    if n_queries > MAX_QUERIES {
        return Err(format!(
            "checkpoint declares {n_queries} named queries, over the {MAX_QUERIES} cap"
        ));
    }
    let mut queries = Vec::with_capacity(n_queries);
    for _ in 0..n_queries {
        let name = dec.str()?;
        if name.is_empty() || name.len() > MAX_QUERY_NAME {
            return Err(format!(
                "query name must be 1–{MAX_QUERY_NAME} bytes, got {} bytes",
                name.len()
            ));
        }
        if queries.iter().any(|q: &QueryRecord| q.name == name) {
            return Err(format!("duplicate query name {name:?}"));
        }
        let half_life = dec.u64()?;
        let window = dec.u64()?;
        let scored = dec.u64()?;
        let cur = decode_levels(dec, levels, buckets, ckpt.cms_rows, ckpt.cms_cols, 5)?;
        let prev = decode_levels(dec, levels, buckets, ckpt.cms_rows, ckpt.cms_cols, 5)?;
        queries.push(QueryRecord { name, half_life, window, scored, cur, prev });
    }
    ckpt.queries = queries;
    Ok(())
}

/// Decode the v≤3 params block. Returns the partially-filled checkpoint
/// (with `cache_total` set to shards × per-shard capacity, clamped to
/// the directory cap) and the raw per-shard capacity for payload
/// validation.
fn decode_header_legacy(dec: &mut Decoder) -> CodecResult<(AbsorbCheckpoint, u64)> {
    // legacy field order: fingerprints, shards, cache-per-shard,
    // submitted, absorb, then the five shape fields
    let model_fingerprint = dec.u32()?;
    let schema_fingerprint = dec.u32()?;
    let shards = dec.u32()?;
    let cache_per_shard = dec.u64()?;
    let mut ckpt = AbsorbCheckpoint {
        model_fingerprint,
        schema_fingerprint,
        shards,
        cache_total: 0,
        submitted: dec.u64()?,
        absorb: match dec.u8()? {
            0 => false,
            1 => true,
            other => return Err(format!("unknown absorb-mode tag {other}")),
        },
        half_life: 0,
        window: 0,
        k: dec.usize()?,
        depth: dec.usize()?,
        num_chains: dec.usize()?,
        cms_rows: dec.usize()?,
        cms_cols: dec.usize()?,
        processed: 0,
        evicted: 0,
        absorbed: 0,
        entries: Vec::new(),
        visible: Vec::new(),
        pending: Vec::new(),
        prev_visible: Vec::new(),
        queries: Vec::new(),
    };
    if cache_per_shard == 0 || cache_per_shard > (1 << 24) {
        return Err(format!(
            "checkpoint cache capacity {cache_per_shard} out of range (1..=2^24)"
        ));
    }
    check_shape(&ckpt)?;
    // legacy budget was per shard; the global directory budget is the
    // pool-wide product, clamped to the same cap the v4 header enforces
    ckpt.cache_total =
        (ckpt.shards as u64).saturating_mul(cache_per_shard).min(1 << 24).max(1);
    Ok((ckpt, cache_per_shard))
}

fn decode_snapshots_legacy(
    dec: &mut Decoder,
    ckpt: &AbsorbCheckpoint,
    cache_per_shard: u64,
    version: u16,
) -> CodecResult<Vec<AbsorbSnapshot>> {
    let n = dec.u32()? as usize;
    if n != ckpt.shards as usize {
        return Err(format!(
            "payload carries {n} snapshots but the header declares {} shards",
            ckpt.shards
        ));
    }
    let levels = ckpt.num_chains * ckpt.depth;
    let buckets = (ckpt.cms_rows * ckpt.cms_cols) as u32;
    let mut snapshots = Vec::with_capacity(n);
    for _ in 0..n {
        let processed = dec.u64()?;
        let evicted = dec.u64()?;
        let absorbed = dec.u64()?;
        let n_entries = dec.u32()? as usize;
        if n_entries as u64 > cache_per_shard {
            return Err(format!(
                "snapshot holds {n_entries} sketches, over the declared cache \
                 capacity {cache_per_shard}"
            ));
        }
        // every entry costs ≥ 12 bytes on the wire; reject declared
        // counts the remaining bytes cannot possibly back
        if dec.remaining() < n_entries.saturating_mul(12) {
            return Err(format!("truncated snapshot: {n_entries} sketch entries declared"));
        }
        let mut entries = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let id = dec.u64()?;
            let sketch = dec.f32_vec()?;
            if sketch.len() != ckpt.k {
                return Err(format!(
                    "sketch for id {id} is {}-wide, header declares K={}",
                    sketch.len(),
                    ckpt.k
                ));
            }
            entries.push((id, sketch));
        }
        let delta = decode_levels(dec, levels, buckets, ckpt.cms_rows, ckpt.cms_cols, version)?;
        snapshots.push(AbsorbSnapshot { processed, evicted, absorbed, entries, delta });
    }
    Ok(snapshots)
}

/// Convert decoded legacy per-shard snapshots into the global v4 form:
/// entries concatenated in shard order with synthesized recency tags
/// (0, 1, 2, … — pre-v4 files never recorded the cross-shard recency
/// interleaving), deltas summed bucket-wise into the visible overlay
/// (legacy absorbs were immediately visible), counters summed, pending
/// empty.
fn convert_legacy(mut ckpt: AbsorbCheckpoint, snapshots: Vec<AbsorbSnapshot>) -> AbsorbCheckpoint {
    let levels = ckpt.num_chains * ckpt.depth;
    let mut maps: Vec<std::collections::HashMap<u32, u32>> =
        vec![std::collections::HashMap::new(); levels];
    let mut seq = 0u64;
    for snap in snapshots {
        ckpt.processed += snap.processed;
        ckpt.evicted += snap.evicted;
        ckpt.absorbed += snap.absorbed;
        for (id, sketch) in snap.entries {
            ckpt.entries.push((id, seq, sketch));
            seq += 1;
        }
        for (map, lvl) in maps.iter_mut().zip(&snap.delta) {
            for &(bucket, count) in lvl {
                let slot = map.entry(bucket).or_insert(0);
                *slot = slot.saturating_add(count);
            }
        }
    }
    ckpt.visible = maps
        .into_iter()
        .map(|map| {
            let mut v: Vec<(u32, u32)> = map.into_iter().collect();
            v.sort_unstable();
            v
        })
        .collect();
    ckpt.pending = vec![Vec::new(); levels];
    ckpt.prev_visible = vec![Vec::new(); levels];
    // a synthesized tag may collide with the submit watermark on
    // degenerate legacy files; keep the v4 invariant tag < submitted
    ckpt.submitted = ckpt.submitted.max(seq);
    ckpt
}

/// Legacy (v≤3) snapshot wire form — kept so the conversion path stays
/// testable against bytes this build itself produced.
#[cfg(test)]
fn encode_snapshot_legacy(enc: &mut Encoder, snap: &AbsorbSnapshot, version: u16) {
    enc.put_u64(snap.processed);
    enc.put_u64(snap.evicted);
    enc.put_u64(snap.absorbed);
    enc.put_u32(snap.entries.len() as u32);
    for (id, sketch) in &snap.entries {
        enc.put_u64(*id);
        enc.put_f32_slice(sketch);
    }
    enc.put_u32(snap.delta.len() as u32);
    for lvl in &snap.delta {
        enc.put_u32(lvl.len() as u32);
        if version >= 3 {
            let mut prev = 0u32;
            for (i, &(bucket, count)) in lvl.iter().enumerate() {
                let gap = if i == 0 { bucket } else { bucket - prev };
                enc.put_varint(gap as u64);
                enc.put_varint(count as u64);
                prev = bucket;
            }
        } else {
            for &(bucket, count) in lvl {
                enc.put_u32(bucket);
                enc.put_u32(count);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AbsorbCheckpoint {
        AbsorbCheckpoint {
            model_fingerprint: 0xDEAD_BEEF,
            schema_fingerprint: 0x5A5A_0001,
            shards: 2,
            cache_total: 8,
            submitted: 17,
            absorb: true,
            half_life: 12,
            window: 8,
            k: 3,
            depth: 2,
            num_chains: 2,
            cms_rows: 4,
            cms_cols: 16,
            processed: 17,
            evicted: 1,
            absorbed: 3,
            entries: vec![
                (7, 3, vec![1.0, -2.0, 0.5]),
                (9, 11, vec![0.0, 0.0, 4.0]),
                (2, 16, vec![0.25, 0.0, -0.0]),
            ],
            visible: vec![vec![(0, 2), (5, 1)], vec![], vec![(63, 4)], vec![]],
            pending: vec![vec![(9, 1)], vec![], vec![], vec![]],
            prev_visible: vec![vec![(2, 7)], vec![(40, 1)], vec![], vec![]],
            queries: vec![
                QueryRecord {
                    name: "decayed.1k".into(),
                    half_life: 4,
                    window: 0,
                    scored: 5,
                    cur: vec![vec![(0, 1)], vec![], vec![], vec![]],
                    prev: vec![vec![], vec![], vec![], vec![]],
                },
                QueryRecord {
                    name: "raw".into(),
                    half_life: 0,
                    window: 0,
                    scored: 0,
                    cur: vec![vec![], vec![], vec![], vec![]],
                    prev: vec![vec![], vec![], vec![], vec![]],
                },
            ],
        }
    }

    #[test]
    fn artifact_round_trip_is_exact() {
        let ckpt = sample();
        let art = ckpt.to_artifact();
        assert_eq!(art.detector, CHECKPOINT_DETECTOR);
        let back = AbsorbCheckpoint::from_artifact(
            &ModelArtifact::from_bytes(&art.to_bytes()).unwrap(),
        )
        .unwrap();
        assert_eq!(ckpt, back);
    }

    #[test]
    fn non_checkpoint_artifacts_are_rejected_typed() {
        let art = ModelArtifact::new("sparx", vec![1, 2], vec![3]);
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&art),
            Err(SparxError::InvalidParams(_))
        ));
    }

    #[test]
    fn inconsistent_blocks_fail_typed() {
        let ckpt = sample();
        // wrong sketch width
        let mut bad = ckpt.clone();
        bad.entries[0].2.push(9.0);
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
        // bucket out of range
        let mut bad = ckpt.clone();
        bad.visible[0].push((4 * 16, 1));
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
        // pending overlay is validated like the visible one
        let mut bad = ckpt.clone();
        bad.pending[1].push((0, 0));
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
        // more entries than the cache budget
        let mut bad = ckpt.clone();
        for id in 100..110u64 {
            let seq = bad.entries.last().map_or(0, |e| e.1) + 1;
            bad.entries.push((id, seq, vec![0.0; 3]));
            bad.submitted = seq + 1;
        }
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
        // recency tags must strictly increase…
        let mut bad = ckpt.clone();
        bad.entries[2].1 = 3;
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
        // …and stay below the submit watermark
        let mut bad = ckpt;
        bad.entries[2].1 = 17;
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
    }

    /// Hand-encode a v4 artifact (params without the decay tail,
    /// payload without the prev block / query records) and check it
    /// still loads — with the decay state defaulted.
    #[test]
    fn v4_files_load_with_decay_state_defaulted() {
        let ckpt = sample();
        let mut params = Encoder::new();
        params.put_u32(ckpt.model_fingerprint);
        params.put_u32(ckpt.schema_fingerprint);
        params.put_u32(ckpt.shards);
        params.put_u64(ckpt.cache_total);
        params.put_u64(ckpt.submitted);
        params.put_u8(u8::from(ckpt.absorb));
        params.put_usize(ckpt.k);
        params.put_usize(ckpt.depth);
        params.put_usize(ckpt.num_chains);
        params.put_usize(ckpt.cms_rows);
        params.put_usize(ckpt.cms_cols);
        params.put_u64(ckpt.processed);
        params.put_u64(ckpt.evicted);
        params.put_u64(ckpt.absorbed);
        let mut payload = Encoder::new();
        payload.put_u32(ckpt.entries.len() as u32);
        for (id, seq, sketch) in &ckpt.entries {
            payload.put_u64(*id);
            payload.put_u64(*seq);
            payload.put_f32_slice(sketch);
        }
        encode_levels(&mut payload, &ckpt.visible);
        encode_levels(&mut payload, &ckpt.pending);
        let mut art =
            ModelArtifact::new(CHECKPOINT_DETECTOR, params.into_bytes(), payload.into_bytes());
        art.version = 4;
        let reread = ModelArtifact::from_bytes(&art.to_bytes()).unwrap();
        assert_eq!(reread.version, 4);
        let back = AbsorbCheckpoint::from_artifact(&reread).unwrap();
        assert_eq!((back.half_life, back.window), (0, 0), "v4 carries no schedule");
        assert_eq!(back.decay(), DecaySpec::default());
        assert_eq!(
            back.prev_visible,
            vec![Vec::new(); 4],
            "prev normalizes to the canonical all-empty M·L shape"
        );
        assert!(back.queries.is_empty());
        assert_eq!(back.entries, ckpt.entries);
        assert_eq!(back.visible, ckpt.visible);
        assert_eq!(back.pending, ckpt.pending);
    }

    #[test]
    fn hostile_v5_decay_blocks_fail_typed() {
        // a schedule without absorb mode is unconstructable live — a
        // file declaring one is corrupt or hostile
        let mut bad = sample();
        bad.absorb = false;
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
        // duplicate query names
        let mut bad = sample();
        bad.queries[1].name = bad.queries[0].name.clone();
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
        // query-name length cap
        let mut bad = sample();
        bad.queries[0].name = "x".repeat(MAX_QUERY_NAME + 1);
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
        // prev block validated like the other overlays
        let mut bad = sample();
        bad.prev_visible[0].push((4 * 16, 1));
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
        // query overlays too
        let mut bad = sample();
        bad.queries[0].cur[0].push((0, 0));
        assert!(matches!(
            AbsorbCheckpoint::from_artifact(&bad.to_artifact()),
            Err(SparxError::InvalidParams(_))
        ));
    }

    #[test]
    fn admitted_counts_entries_plus_evictions() {
        // validate_for itself needs a live ensemble — exercised in
        // tests/checkpoint.rs; here pin the counter identity
        assert_eq!(sample().admitted(), 1 + 3);
    }

    /// Build a legacy (pre-v4) artifact byte-for-byte — params block in
    /// the old field order, payload as per-shard snapshots — and check
    /// the conversion: entries concatenated with synthesized recency
    /// tags, deltas merged into the visible overlay, counters summed.
    fn legacy_artifact(version: u16) -> ModelArtifact {
        let mut params = Encoder::new();
        params.put_u32(0xDEAD_BEEF);
        params.put_u32(0x5A5A_0001);
        params.put_u32(2); // shards
        params.put_u64(4); // cache per shard
        params.put_u64(17); // submitted
        params.put_u8(1); // absorb
        params.put_usize(3); // k
        params.put_usize(2); // depth
        params.put_usize(2); // num_chains
        params.put_usize(4); // cms_rows
        params.put_usize(16); // cms_cols
        let snapshots = vec![
            AbsorbSnapshot {
                processed: 10,
                evicted: 1,
                absorbed: 3,
                entries: vec![(7, vec![1.0, -2.0, 0.5]), (9, vec![0.0, 0.0, 4.0])],
                delta: vec![vec![(0, 2), (5, 1)], vec![], vec![(63, 4)], vec![]],
            },
            AbsorbSnapshot {
                processed: 7,
                evicted: 0,
                absorbed: 0,
                entries: vec![(2, vec![0.25, 0.0, -0.0])],
                delta: vec![vec![(5, 2)], vec![], vec![], vec![]],
            },
        ];
        let mut payload = Encoder::new();
        payload.put_u32(snapshots.len() as u32);
        for snap in &snapshots {
            encode_snapshot_legacy(&mut payload, snap, version);
        }
        let mut art =
            ModelArtifact::new(CHECKPOINT_DETECTOR, params.into_bytes(), payload.into_bytes());
        art.version = version;
        art
    }

    #[test]
    fn legacy_checkpoint_payloads_decode_and_convert() {
        for version in [2u16, 3] {
            let art = legacy_artifact(version);
            let reread = ModelArtifact::from_bytes(&art.to_bytes()).unwrap();
            assert_eq!(reread.version, version);
            let ckpt = AbsorbCheckpoint::from_artifact(&reread).unwrap();
            assert_eq!(ckpt.model_fingerprint, 0xDEAD_BEEF);
            assert_eq!(ckpt.shards, 2);
            assert_eq!(ckpt.cache_total, 8, "per-shard budget × shards");
            assert_eq!(ckpt.submitted, 17);
            assert!(ckpt.absorb);
            assert_eq!((ckpt.processed, ckpt.evicted, ckpt.absorbed), (17, 1, 3));
            // entries in shard order with synthesized recency tags
            assert_eq!(
                ckpt.entries,
                vec![
                    (7, 0, vec![1.0, -2.0, 0.5]),
                    (9, 1, vec![0.0, 0.0, 4.0]),
                    (2, 2, vec![0.25, 0.0, -0.0]),
                ]
            );
            // deltas merged bucket-wise into the visible overlay
            assert_eq!(ckpt.visible[0], vec![(0, 2), (5, 3)]);
            assert_eq!(ckpt.visible[2], vec![(63, 4)]);
            assert!(ckpt.pending.iter().all(Vec::is_empty));
            assert_eq!(ckpt.admitted(), 1 + 3);
        }
    }

    /// The v3 gap-varint level codec compresses vs the raw v2 pairs.
    #[test]
    fn v3_levels_are_smaller_than_v2() {
        let a2 = legacy_artifact(2);
        let a3 = legacy_artifact(3);
        assert!(a3.payload.len() < a2.payload.len(), "v3 must compress the delta levels");
        // both decode to the same converted checkpoint
        assert_eq!(
            AbsorbCheckpoint::from_artifact(&a2).unwrap(),
            AbsorbCheckpoint::from_artifact(&a3).unwrap()
        );
    }
}
