//! Fused multi-chain partition executors — the single-pass execution
//! plan restoring the paper's §3.4 O(1)-passes-in-M structure.
//!
//! The per-chain path (kept behind [`ExecMode::PerChain`]) runs a full
//! `map_partitions` + `aggregate` round per chain during fit and a full
//! pass per chain during scoring — M rounds and M re-flattenings of the
//! sketch block for an M-chain ensemble. The fused plan here drives **one
//! partition visit** that flattens the sketch block once, bins every
//! chain against it through [`Binner::tile_bins_multi`], and
//!
//! * **fit** — emits one concatenated `[M][L][r][w]` count block per
//!   partition, reduced by a single worker-side-combining
//!   [`DistVec::tree_aggregate`] round (M·L·r·w bytes cross the network
//!   once per worker, one ledger round total);
//! * **score** — folds min-over-levels per chain and sum-over-chains into
//!   a per-point accumulator inside the same visit (no per-chain
//!   `DistVec`s, no `zip_map` chain), emitting `(id, outlierness)`
//!   directly.
//!
//! Both executors are numerically identical to the per-chain path: counts
//! are order-independent `u32` sums, and the score accumulator adds
//! chains in ascending order — the same left-fold the per-chain path
//! performs — so scores match bit for bit (asserted in `ensemble` tests).

use crate::cluster::dist::Broadcast;
use crate::cluster::{ClusterContext, ClusterError, DistVec, Result};
use crate::util::Rng;

use super::chain::{Binner, ChainParams};
use super::cms::CountMinSketch;
use super::ensemble::{score_bins_tile, SparxModel, SparxParams, TrainedChain};
use super::projector::Sketch;

/// Execution strategy for distributed fit/score.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// One `map_partitions` + `aggregate` round *per chain* (the original
    /// path, kept for A/B comparison in fig5/fig6 and the benches).
    PerChain,
    /// All M chains in one fit pass and one score pass (paper-faithful).
    Fused,
}

impl ExecMode {
    /// Both plans in A/B order (fused first) — what fig5/fig6 and the
    /// hotpath bench iterate over.
    pub const ALL: [ExecMode; 2] = [ExecMode::Fused, ExecMode::PerChain];

    /// Short label for CLI output, experiment rows and bench names.
    pub fn tag(self) -> &'static str {
        match self {
            ExecMode::PerChain => "per-chain",
            ExecMode::Fused => "fused",
        }
    }
}

/// All sampled chain parameters of an ensemble plus the CMS shape — the
/// driver-resident plan a fused pass executes against.
pub struct ChainSet {
    pub chains: Vec<ChainParams>,
    /// Chain length L.
    pub l: usize,
    /// CMS hash tables r.
    pub r: usize,
    /// CMS buckets per table w.
    pub w: usize,
    /// Projected dimensionality K.
    pub k: usize,
    sample_rate: f64,
    seed: u64,
}

/// Shared CMS-shape guard for both fit executors: bucket coordinates
/// must stay packable into shuffle keys. One implementation so the two
/// [`ExecMode`]s can never diverge in which parameter sets they accept.
/// (The same bound is enforced up front, with the rest of the
/// hyperparameter rules, by `SparxParams::validate` — this guard stays
/// for callers that drive the executors directly.)
pub(crate) fn check_cms_shape(r: usize, w: usize) -> Result<()> {
    if r >= 128 || w >= (1 << 20) {
        return Err(ClusterError::Invalid("CMS too large for shuffle key packing".into()));
    }
    Ok(())
}

/// Bound the transient `[chunk][n][L][K]` bins buffer a fused executor
/// asks the binner for (chains are processed in ascending chunks; one
/// chain minimum so progress is always possible).
fn chains_per_chunk(n: usize, l: usize, k: usize) -> usize {
    const BINS_BUDGET_BYTES: usize = 32 << 20;
    let per_chain = n.max(1) * l.max(1) * k.max(1) * std::mem::size_of::<i32>();
    (BINS_BUDGET_BYTES / per_chain).max(1)
}

/// Scatter one chain's `[n][L][K]` bin ids into its `[L][r][w]` count
/// block (the map-side combine of Alg. 2's `((level,row,col),1)` pairs —
/// numerically identical to reduceByKey over the raw pairs). Shared by
/// the fused and per-chain fit executors.
pub(crate) fn accumulate_counts(
    bins: &[i32],
    n: usize,
    l: usize,
    k: usize,
    r: usize,
    w: usize,
    counts: &mut [u32],
) {
    debug_assert_eq!(bins.len(), n * l * k);
    debug_assert_eq!(counts.len(), l * r * w);
    for i in 0..n {
        for lvl in 0..l {
            let bin = &bins[(i * l + lvl) * k..(i * l + lvl + 1) * k];
            // hash once, then derive all r buckets branch-free; counts
            // saturate (consistent with CountMinSketch) instead of wrapping
            let mut walk = crate::hash::BucketWalk::new(crate::hash::bin_hash(bin), w);
            let block = &mut counts[lvl * r * w..(lvl + 1) * r * w];
            let mut base = 0usize;
            for _ in 0..r {
                let slot = &mut block[base + walk.next_bucket()];
                *slot = slot.saturating_add(1);
                base += w;
            }
        }
    }
}

/// The parameter-sampling RNG stream of chain `m` — the single seed
/// schedule shared by the fused plan, the per-chain executor
/// (`SparxModel::fit_chains`) and single-machine xStream, so all three
/// fit identical chain parameters from one `SparxParams::seed`.
pub(crate) fn chain_rng(seed: u64, m: usize) -> Rng {
    Rng::new(seed.wrapping_add(m as u64 * 0x9E37_79B9))
}

impl ChainSet {
    /// Sample all M chains with the same per-chain seed schedule the
    /// per-chain path (and single-machine xStream) uses, so fitted
    /// parameters are identical across execution modes.
    pub fn sample(deltamax: &[f32], params: &SparxParams) -> ChainSet {
        let chains = (0..params.num_chains)
            .map(|m| {
                let mut rng = chain_rng(params.seed, m);
                ChainParams::sample(deltamax, params.depth, &mut rng)
            })
            .collect();
        ChainSet {
            chains,
            l: params.depth,
            r: params.cms_rows,
            w: params.cms_cols,
            k: deltamax.len(),
            sample_rate: params.sample_rate,
            seed: params.seed,
        }
    }

    pub fn num_chains(&self) -> usize {
        self.chains.len()
    }

    /// Length of the fused `[M][L][r][w]` count block in u32s — the
    /// constant-size intermediate a fused fit ships per worker.
    pub fn block_len(&self) -> usize {
        self.chains.len() * self.l * self.r * self.w
    }

    /// Fused fit: one partition visit bins every chain against the
    /// once-flattened sketch block; one tree-aggregate round reduces the
    /// concatenated count blocks. At `sample_rate < 1` the per-chain
    /// Bernoulli masks replicate `DistVec::sample`'s per-(seed, partition)
    /// stream exactly, so counts match the per-chain path bit for bit.
    pub fn fit(
        &self,
        ctx: &ClusterContext,
        proj: &DistVec<Sketch>,
        binner: &dyn Binner,
    ) -> Result<Vec<TrainedChain>> {
        check_cms_shape(self.r, self.w)?;
        let (m, l, r, w, k) = (self.chains.len(), self.l, self.r, self.w, self.k);
        let per_chain = l * r * w;
        let block = self.block_len();
        let rate = self.sample_rate;
        let seed = self.seed;
        let total = proj.tree_aggregate(
            ctx,
            vec![0u32; block],
            |p, part| {
                let n = part.len();
                // flatten the sketch block ONCE per partition (the
                // per-chain path repeats this M times)
                let mut flat = Vec::with_capacity(n * k);
                for sk in part {
                    flat.extend_from_slice(&sk.s);
                }
                let mut counts = vec![0u32; block];
                if rate >= 1.0 {
                    // every chain bins the same tile: multi-chain entry
                    // point, chunked to bound the bins buffer
                    let refs: Vec<&ChainParams> = self.chains.iter().collect();
                    let chunk = chains_per_chunk(n, l, k);
                    let mut m0 = 0;
                    while m0 < m {
                        let mc = chunk.min(m - m0);
                        let bins = binner.tile_bins_multi(&refs[m0..m0 + mc], &flat, n)?;
                        for j in 0..mc {
                            accumulate_counts(
                                &bins[j * n * l * k..(j + 1) * n * l * k],
                                n,
                                l,
                                k,
                                r,
                                w,
                                &mut counts[(m0 + j) * per_chain..(m0 + j + 1) * per_chain],
                            );
                        }
                        m0 += mc;
                    }
                } else {
                    // per-chain subsample inside the single visit: one
                    // Bernoulli draw per point in partition order from
                    // the same (seed ^ m, p) stream DistVec::sample uses
                    // on the per-chain path
                    let mut sub: Vec<f32> = Vec::new();
                    for (mi, chain) in self.chains.iter().enumerate() {
                        let mut rng = crate::cluster::dist::partition_rng(seed ^ mi as u64, p);
                        sub.clear();
                        let mut ns = 0usize;
                        for i in 0..n {
                            if rng.bool(rate) {
                                sub.extend_from_slice(&flat[i * k..(i + 1) * k]);
                                ns += 1;
                            }
                        }
                        let bins = binner.tile_bins(chain, &sub, ns)?;
                        accumulate_counts(
                            &bins,
                            ns,
                            l,
                            k,
                            r,
                            w,
                            &mut counts[mi * per_chain..(mi + 1) * per_chain],
                        );
                    }
                }
                Ok(counts)
            },
            |mut a, b| {
                for (x, y) in a.iter_mut().zip(&b) {
                    *x = x.saturating_add(*y);
                }
                a
            },
        )?;
        Ok(self
            .chains
            .iter()
            .enumerate()
            .map(|(mi, cp)| {
                let base = mi * per_chain;
                let cms = (0..l)
                    .map(|lvl| {
                        CountMinSketch::from_counts(
                            r,
                            w,
                            &total[base + lvl * r * w..base + (lvl + 1) * r * w],
                        )
                    })
                    .collect();
                TrainedChain { params: cp.clone(), cms }
            })
            .collect())
    }
}

/// Fused score: broadcast the ensemble once, then a single partition
/// visit flattens the sketch block once, bins chains in ascending chunks,
/// and folds Eq. (5) per point — min over levels (via the level-major
/// [`score_bins_tile`] kernel), sum over chains in chain order (the
/// per-chain path's exact fold order), emitting `(id, -avg)` directly.
pub(crate) fn score_fused(
    model: &SparxModel,
    ctx: &ClusterContext,
    proj: &DistVec<Sketch>,
    binner: &dyn Binner,
) -> Result<Vec<(u64, f64)>> {
    if model.chains.is_empty() {
        return Err(ClusterError::Invalid("no chains".into()));
    }
    let bcast: Broadcast<Vec<TrainedChain>> = Broadcast::new(ctx, model.chains.clone())?;
    let mode = model.params.score_mode;
    let k = model.deltamax.len();
    let l = model.params.depth;
    let m = model.chains.len();
    let scored = proj.map_partitions(ctx, |_, part| {
        let chains = bcast.value();
        let n = part.len();
        let mut flat = Vec::with_capacity(n * k);
        for sk in part {
            flat.extend_from_slice(&sk.s);
        }
        let mut totals = vec![0f64; n];
        let chunk = chains_per_chunk(n, l, k);
        let mut m0 = 0;
        while m0 < m {
            let mc = chunk.min(m - m0);
            let refs: Vec<&ChainParams> = chains[m0..m0 + mc].iter().map(|c| &c.params).collect();
            let bins = binner.tile_bins_multi(&refs, &flat, n)?;
            for j in 0..mc {
                let chain = &chains[m0 + j];
                // level-major tile kernel: same per-point value fold as
                // score_bins, one CMS cache-hot across the whole tile
                let span = &bins[j * n * l * k..(j + 1) * n * l * k];
                score_bins_tile(chain, mode, span, n, &mut totals);
            }
            m0 += mc;
        }
        Ok(part
            .iter()
            .zip(&totals)
            .map(|(sk, &t)| (sk.id, -(t / m as f64)))
            .collect())
    })?;
    scored.collect(ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::data::generators::GisetteGen;
    use crate::sparx::chain::NativeBinner;
    use crate::sparx::projector::{compute_deltamax, project_dataset};

    fn ctx() -> ClusterContext {
        ClusterConfig { num_partitions: 4, num_workers: 2, num_threads: 2, ..Default::default() }
            .build()
    }

    #[test]
    fn chain_set_samples_the_per_chain_schedule() {
        let delta = vec![1.0f32, 2.0, 0.5];
        let params = SparxParams { num_chains: 6, depth: 5, ..Default::default() };
        let set = ChainSet::sample(&delta, &params);
        assert_eq!(set.num_chains(), 6);
        for (m, chain) in set.chains.iter().enumerate() {
            let mut rng = Rng::new(params.seed.wrapping_add(m as u64 * 0x9E37_79B9));
            let want = ChainParams::sample(&delta, params.depth, &mut rng);
            assert_eq!(*chain, want, "chain {m} diverges from the per-chain seed schedule");
        }
    }

    #[test]
    fn fused_fit_counts_equal_per_chain_fit_at_subsample() {
        // exercises the Bernoulli-mask replication (rate < 1)
        let c = ctx();
        let ld = GisetteGen { n: 500, d: 24, ..Default::default() }.generate(&c).unwrap();
        let params = SparxParams {
            k: 8,
            num_chains: 5,
            depth: 4,
            sample_rate: 0.4,
            ..Default::default()
        };
        let projector = SparxModel::make_projector(&ld.dataset, &params);
        let proj = project_dataset(&c, &ld.dataset, &projector).unwrap();
        let deltamax = compute_deltamax(&c, &proj).unwrap();
        let fused = ChainSet::sample(&deltamax, &params).fit(&c, &proj, &NativeBinner).unwrap();
        let per_chain =
            SparxModel::fit_chains(&c, &proj, &deltamax, &params, &NativeBinner).unwrap();
        assert_eq!(fused.len(), per_chain.len());
        for (a, b) in fused.iter().zip(&per_chain) {
            assert_eq!(a.params, b.params);
            assert_eq!(a.cms, b.cms, "subsampled counts diverge between executors");
        }
    }

    #[test]
    fn chunking_bounds_hold() {
        assert_eq!(chains_per_chunk(0, 0, 0), (32 << 20) / 4);
        assert!(chains_per_chunk(1_000_000, 20, 100) >= 1);
        // a tiny tile fits many chains per chunk
        assert!(chains_per_chunk(10, 5, 8) > 50);
    }
}
