//! Streaming front-end (§3.5, Problem 2): a single deployment node that
//! receives ⟨ID, F, δ⟩ update triples over an *evolving* stream and
//! returns the updated outlier score in constant time.
//!
//! * sketches of the N most recently touched IDs live in an LRU cache —
//!   O(N·K) space;
//! * a δ-update adjusts K sketch entries via Eq. (3) — O(K) time — and
//!   works for **never-before-seen features** because the projection
//!   entries are hashed on the fly, not cached;
//! * re-scoring reads r buckets per level per chain — O(K + rLM) time;
//! * the model (all CMSes) is O(rwLM) — constant in n and d.

use crate::api::{Result, SparxError};
use crate::util::LruCache;

use super::ensemble::{score_bins, ScoreMode, SparxModel, TrainedChain};
use crate::data::UpdateTriple;

/// Outcome of one streamed update.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamScore {
    pub id: u64,
    /// Higher = more outlying (same convention as batch scoring).
    pub outlierness: f64,
    /// Whether the point was newly admitted to the cache by this update.
    pub fresh: bool,
}

impl StreamScore {
    /// Whether this score is strictly more outlying than `current`
    /// (`None` loses — the first score seen always wins). The one
    /// comparison every "most outlying update" tracker shares. Strict
    /// `>` means ties keep the earliest candidate *a given tracker*
    /// saw: per shard that is stream order, and the cross-shard merge
    /// then prefers the lowest shard index among bit-equal scores.
    pub fn more_outlying_than(&self, current: Option<&StreamScore>) -> bool {
        match current {
            None => true,
            Some(w) => self.outlierness > w.outlierness,
        }
    }
}

/// The deployment-node scorer.
pub struct StreamScorer {
    chains: Vec<TrainedChain>,
    projector: crate::sparx::Projector,
    mode: ScoreMode,
    k: usize,
    cache: LruCache<u64, Vec<f32>>,
    // scratch buffers reused across updates (no allocation per update)
    scratch: Vec<f32>,
    bins: Vec<i32>,
    evicted: u64,
    processed: u64,
}

impl StreamScorer {
    /// Build from a fitted model with an LRU capacity of `cache_size` IDs.
    /// Requires a hashing projector (k > 0): evolving features need the
    /// hash-not-cash trick of Eq. (2)/(3).
    pub fn new(model: &SparxModel, cache_size: usize) -> Result<Self> {
        if cache_size == 0 {
            return Err(SparxError::InvalidParams(
                "stream cache size must be ≥ 1 (it bounds the resident sketches)".into(),
            ));
        }
        if model.projector.is_identity() {
            return Err(SparxError::Unsupported(
                "streaming requires a hashing projector (params.k > 0)".into(),
            ));
        }
        let k = model.projector.k();
        let depth = model.params.depth;
        Ok(StreamScorer {
            chains: model.chains.clone(),
            projector: model.projector.clone(),
            mode: model.params.score_mode,
            k,
            cache: LruCache::new(cache_size),
            scratch: vec![0.0; k],
            bins: vec![0; depth * k],
            evicted: 0,
            processed: 0,
        })
    }

    /// Apply one ⟨ID, F, δ⟩ update (Eq. 3) and return the updated score.
    pub fn update(&mut self, u: &UpdateTriple) -> StreamScore {
        self.processed += 1;
        let id = u.id();
        let fresh = !self.cache.contains(&id);
        if fresh && self.cache.put(id, vec![0.0f32; self.k]).is_some() {
            self.evicted += 1;
        }
        {
            let s = self.cache.get_mut(&id).expect("just inserted");
            match u {
                UpdateTriple::Num { feature, delta, .. } => {
                    // s[k] += h_k(F) · δ — works for brand-new features too
                    for (sk, h) in s.iter_mut().zip(&self.projector.hashers) {
                        *sk += h.feature(feature) * *delta as f32;
                    }
                }
                UpdateTriple::Cat { feature, old, new, .. } => {
                    // s[k] += h_k(F⊕new) − h_k(F⊕old); old = null ⇒ 0
                    for (sk, h) in s.iter_mut().zip(&self.projector.hashers) {
                        *sk += h.feature_value(feature, new);
                        if let Some(o) = old {
                            *sk -= h.feature_value(feature, o);
                        }
                    }
                }
            }
        }
        let outlierness = self.score_id(id).expect("cached");
        StreamScore { id, outlierness, fresh }
    }

    /// Score a cached ID against the ensemble: O(rLM) CMS reads, zero
    /// allocations (scratch buffers are reused across updates). Uses the
    /// same [`score_bins`] kernel as the distributed and fused scorers.
    pub fn score_id(&mut self, id: u64) -> Option<f64> {
        let s = self.cache.get(&id)?; // disjoint field borrows below
        let mut total = 0.0;
        for chain in &self.chains {
            chain.params.bins_into(s, &mut self.scratch, &mut self.bins);
            total += score_bins(chain, self.mode, &self.bins);
        }
        Some(-(total / self.chains.len() as f64))
    }

    /// Absorb the point's current sketch into the density counts (the
    /// xStream streaming behaviour: new points update the histograms).
    pub fn absorb(&mut self, id: u64) -> bool {
        let Some(s) = self.cache.get(&id).cloned() else { return false };
        let k = self.k;
        for chain in &mut self.chains {
            chain.params.bins_into(&s, &mut self.scratch, &mut self.bins);
            for (lvl, cms) in chain.cms.iter_mut().enumerate() {
                cms.insert(&self.bins[lvl * k..(lvl + 1) * k]);
            }
        }
        true
    }

    pub fn cached_ids(&self) -> usize {
        self.cache.len()
    }

    pub fn evictions(&self) -> u64 {
        self.evicted
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// The dense feature names the model was trained against, if its
    /// projector carries a schema (used by `sparx serve` to synthesize a
    /// compatible demo stream; any names hash fine either way).
    pub fn feature_names(&self) -> Option<&[String]> {
        self.projector.dense_schema()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::data::generators::GisetteGen;
    use crate::sparx::SparxParams;

    fn fitted() -> SparxModel {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 400, d: 24, ..Default::default() }.generate(&ctx).unwrap();
        SparxModel::fit(
            &ctx,
            &ld.dataset,
            &SparxParams { k: 8, num_chains: 8, depth: 5, ..Default::default() },
        )
        .unwrap()
    }

    #[test]
    fn updates_accumulate() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 16).unwrap();
        let a = s.update(&UpdateTriple::Num { id: 1, feature: "f0".into(), delta: 1.0 });
        assert!(a.fresh);
        let b = s.update(&UpdateTriple::Num { id: 1, feature: "f0".into(), delta: 1.0 });
        assert!(!b.fresh);
        // two +1 updates must equal one +2 update on a fresh id
        let c2 = s.update(&UpdateTriple::Num { id: 2, feature: "f0".into(), delta: 2.0 });
        assert!((b.outlierness - c2.outlierness).abs() < 1e-9);
    }

    #[test]
    fn categorical_substitution_cancels() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 16).unwrap();
        let base = s.update(&UpdateTriple::Num { id: 5, feature: "f1".into(), delta: 0.7 });
        // NYC then NYC→Austin then Austin→NYC must return to the NYC state
        let _ = s.update(&UpdateTriple::Cat {
            id: 5,
            feature: "loc".into(),
            old: None,
            new: "NYC".into(),
        });
        let nyc1 = s.score_id(5).unwrap();
        let _ = s.update(&UpdateTriple::Cat {
            id: 5,
            feature: "loc".into(),
            old: Some("NYC".into()),
            new: "Austin".into(),
        });
        let _ = s.update(&UpdateTriple::Cat {
            id: 5,
            feature: "loc".into(),
            old: Some("Austin".into()),
            new: "NYC".into(),
        });
        let nyc2 = s.score_id(5).unwrap();
        assert!((nyc1 - nyc2).abs() < 1e-6, "{nyc1} vs {nyc2}");
        let _ = base;
    }

    #[test]
    fn brand_new_feature_accepted() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 16).unwrap();
        let r = s.update(&UpdateTriple::Num {
            id: 9,
            feature: "never_seen_indicator_42".into(),
            delta: 3.0,
        });
        assert!(r.outlierness.is_finite());
    }

    #[test]
    fn lru_bounds_memory() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 8).unwrap();
        for id in 0..100 {
            s.update(&UpdateTriple::Num { id, feature: "f0".into(), delta: 1.0 });
        }
        assert_eq!(s.cached_ids(), 8);
        assert_eq!(s.evictions(), 92);
        assert_eq!(s.processed(), 100);
    }

    /// Eviction starts exactly at `cache_size`: filling the cache costs
    /// nothing, the first id beyond it evicts.
    #[test]
    fn eviction_starts_exactly_at_cache_size() {
        let model = fitted();
        let cache_size = 6;
        let mut s = StreamScorer::new(&model, cache_size).unwrap();
        for id in 0..cache_size as u64 {
            s.update(&UpdateTriple::Num { id, feature: "f0".into(), delta: 1.0 });
        }
        assert_eq!(s.cached_ids(), cache_size);
        assert_eq!(s.evictions(), 0, "filling to capacity must not evict");
        s.update(&UpdateTriple::Num { id: 999, feature: "f0".into(), delta: 1.0 });
        assert_eq!(s.cached_ids(), cache_size);
        assert_eq!(s.evictions(), 1, "one past capacity evicts exactly one");
        assert_eq!(s.processed(), cache_size as u64 + 1);
    }

    /// An evicted id that comes back is `fresh` again and restarts from a
    /// zero sketch — its score equals the original first-update score,
    /// not the accumulated state from before eviction.
    #[test]
    fn readmission_after_eviction_is_fresh_with_reset_state() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 4).unwrap();
        let first = s.update(&UpdateTriple::Num { id: 0, feature: "f0".into(), delta: 1.0 });
        assert!(first.fresh);
        // accumulate more state on id 0, then push it out with 4 new ids
        let second = s.update(&UpdateTriple::Num { id: 0, feature: "f0".into(), delta: 1.0 });
        assert!(!second.fresh, "cached id must not be fresh");
        for id in 1..=4 {
            s.update(&UpdateTriple::Num { id, feature: "f0".into(), delta: 1.0 });
        }
        assert!(s.evictions() >= 1, "id 0 must have been evicted");
        assert!(s.score_id(0).is_none(), "evicted id has no cached sketch");
        let back = s.update(&UpdateTriple::Num { id: 0, feature: "f0".into(), delta: 1.0 });
        assert!(back.fresh, "re-admission after eviction must set fresh again");
        assert_eq!(
            back.outlierness, first.outlierness,
            "re-admitted sketch must restart from zero, not resume"
        );
        assert_eq!(s.processed(), 7);
    }

    #[test]
    fn absorb_increases_density_at_point() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 16).unwrap();
        let before = s.update(&UpdateTriple::Num { id: 3, feature: "f2".into(), delta: 5.0 });
        // absorbing the point several times makes its region denser ⇒ its
        // outlierness must strictly drop
        for _ in 0..5 {
            assert!(s.absorb(3));
        }
        let after = s.score_id(3).unwrap();
        assert!(after < before.outlierness, "{after} !< {}", before.outlierness);
    }

    #[test]
    fn zero_cache_size_is_a_typed_error_not_a_panic() {
        let model = fitted();
        assert!(matches!(
            StreamScorer::new(&model, 0),
            Err(crate::api::SparxError::InvalidParams(_))
        ));
    }

    #[test]
    fn identity_model_rejected() {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = crate::data::generators::OsmGen {
            n_inliers: 500,
            n_outliers: 5,
            roads: 5,
            cities: 3,
            ..Default::default()
        }
        .generate(&ctx)
        .unwrap();
        let model = SparxModel::fit(
            &ctx,
            &ld.dataset,
            &SparxParams { k: 0, num_chains: 4, depth: 4, ..Default::default() },
        )
        .unwrap();
        assert!(StreamScorer::new(&model, 8).is_err());
    }
}
