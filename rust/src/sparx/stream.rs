//! Streaming front-end (§3.5, Problem 2): a single deployment node that
//! receives ⟨ID, F, δ⟩ update triples over an *evolving* stream and
//! returns the updated outlier score in constant time.
//!
//! * sketches of the N most recently touched IDs live in an LRU cache —
//!   O(N·K) space;
//! * a δ-update adjusts K sketch entries via Eq. (3) — O(K) time — and
//!   works for **never-before-seen features** because the projection
//!   entries are hashed on the fly, not cached;
//! * re-scoring reads r buckets per level per chain — O(K + rLM) time;
//! * the model (all CMSes) is O(rwLM) — constant in n and d.
//!
//! ## Served state split (read-only ensemble vs mutable absorb state)
//!
//! The scorer is split into two halves with very different lifecycles:
//!
//! * [`ServedEnsemble`] — the **read-only** fitted model (chains, trained
//!   CMS counts, projector, bin schema). It lives behind an `Arc`, so S
//!   shard workers share **one** copy at 1× the model footprint instead
//!   of cloning it S times — and because scoring only reads it, sharing
//!   cannot move a score by even a bit.
//! * the **mutable absorb state** owned by each [`StreamScorer`]: the LRU
//!   sketch cache plus a sparse *delta* overlay of absorbed CMS counts
//!   ([`super::cms::CountMinSketch::query_overlaid`]). Absorbing a point
//!   increments the overlay, never the shared base counts. This state is small,
//!   per-shard, serializable ([`StreamScorer::snapshot`] /
//!   [`StreamScorer::restore`] — see [`super::checkpoint`]) and survives
//!   a hot model swap ([`StreamScorer::swap_ensemble`]).

use std::collections::HashMap;
use std::sync::Arc;

use crate::api::{Result, SparxError};
use crate::util::codec::{crc32, Encoder};
use crate::util::LruCache;

use super::checkpoint::AbsorbSnapshot;
use super::cms::decay_halve_overlay;
use super::ensemble::{
    score_bins, score_bins_overlaid, score_bins_overlaid2, ScoreMode, SparxModel, TrainedChain,
};
use crate::data::UpdateTriple;

/// Outcome of one streamed update.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamScore {
    pub id: u64,
    /// Higher = more outlying (same convention as batch scoring).
    pub outlierness: f64,
    /// Whether the point was newly admitted to the cache by this update.
    pub fresh: bool,
}

impl StreamScore {
    /// Whether this score is strictly more outlying than `current`
    /// (`None` loses — the first score seen always wins). The one
    /// comparison every "most outlying update" tracker shares. Strict
    /// `>` means ties keep the earliest candidate *a given tracker*
    /// saw: per shard that is stream order, and the cross-shard merge
    /// then prefers the lowest shard index among bit-equal scores.
    pub fn more_outlying_than(&self, current: Option<&StreamScore>) -> bool {
        match current {
            None => true,
            Some(w) => self.outlierness > w.outlierness,
        }
    }
}

/// What a hot model swap carries forward (see
/// [`ServedEnsemble::swap_carry`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapCarry {
    /// Same fitted model (fingerprint match): sketches, counters **and**
    /// the absorbed CMS delta all carry forward.
    Full,
    /// Same serving schema but different fitted chains: sketches and
    /// counters carry forward; the absorbed delta is reset, because its
    /// bucket indices were computed against the old chains' bins.
    SketchesOnly,
}

/// The read-only half of the serving state: everything scoring needs and
/// nothing a δ-update mutates. Build once per loaded model
/// ([`ServedEnsemble::new`] or `FittedModel::served_ensemble` on the
/// api), wrap in an `Arc`, and hand the same handle to every shard.
pub struct ServedEnsemble {
    pub(crate) chains: Vec<TrainedChain>,
    pub(crate) projector: super::Projector,
    mode: ScoreMode,
    k: usize,
    depth: usize,
    cms_rows: usize,
    cms_cols: usize,
    /// CRC-32 over the encoded projector + score mode + every trained
    /// chain: two ensembles score identically iff this matches.
    model_fingerprint: u32,
    /// CRC-32 over the *serving schema* only (projection width/density,
    /// ensemble shape, score mode, dense feature names): absorb state is
    /// portable between ensembles exactly when this matches.
    schema_fingerprint: u32,
}

impl ServedEnsemble {
    /// Freeze a fitted model's scoring state. Requires a hashing
    /// projector (k > 0): evolving features need the hash-not-cash trick
    /// of Eq. (2)/(3).
    pub fn new(model: &SparxModel) -> Result<ServedEnsemble> {
        if model.projector.is_identity() {
            return Err(SparxError::Unsupported(
                "streaming requires a hashing projector (params.k > 0)".into(),
            ));
        }
        if model.chains.is_empty() || model.chains[0].cms.is_empty() {
            return Err(SparxError::InvalidParams(
                "cannot serve an ensemble with no trained chains".into(),
            ));
        }
        let k = model.projector.k();
        let depth = model.params.depth;
        let (cms_rows, cms_cols) = (model.chains[0].cms[0].rows(), model.chains[0].cms[0].cols());
        let mut ens = ServedEnsemble {
            chains: model.chains.clone(),
            projector: model.projector.clone(),
            mode: model.params.score_mode,
            k,
            depth,
            cms_rows,
            cms_cols,
            model_fingerprint: 0,
            schema_fingerprint: 0,
        };
        ens.model_fingerprint = ens.compute_model_fingerprint();
        ens.schema_fingerprint = ens.compute_schema_fingerprint();
        Ok(ens)
    }

    fn compute_model_fingerprint(&self) -> u32 {
        let mut enc = Encoder::new();
        crate::api::artifact::encode_projector(&mut enc, &self.projector);
        crate::api::artifact::encode_score_mode(&mut enc, self.mode);
        for chain in &self.chains {
            // pinned to the v2 (raw-counts) chain encoding: the
            // fingerprint is a stable identity for "same fitted model",
            // and must not change when the artifact payload codec does
            crate::api::artifact::encode_chain(&mut enc, chain, 2);
        }
        crc32(enc.as_slice())
    }

    fn compute_schema_fingerprint(&self) -> u32 {
        let mut enc = Encoder::new();
        enc.put_usize(self.k);
        enc.put_f64(self.projector.density().unwrap_or(0.0));
        enc.put_usize(self.depth);
        enc.put_usize(self.chains.len());
        enc.put_usize(self.cms_rows);
        enc.put_usize(self.cms_cols);
        crate::api::artifact::encode_score_mode(&mut enc, self.mode);
        match self.projector.dense_schema() {
            None => enc.put_u8(0),
            Some(names) => {
                enc.put_u8(1);
                enc.put_u32(names.len() as u32);
                for n in names {
                    enc.put_str(n);
                }
            }
        }
        crc32(enc.as_slice())
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn num_chains(&self) -> usize {
        self.chains.len()
    }

    pub fn cms_rows(&self) -> usize {
        self.cms_rows
    }

    pub fn cms_cols(&self) -> usize {
        self.cms_cols
    }

    pub fn score_mode(&self) -> ScoreMode {
        self.mode
    }

    /// CRC-32 over the encoded projector + score mode + every trained
    /// chain: two ensembles score identically iff this matches. Resume
    /// (`serve --resume`) requires equality — a checkpoint only
    /// reproduces the interrupted stream bit-for-bit under the exact
    /// model it was taken against.
    pub fn model_fingerprint(&self) -> u32 {
        self.model_fingerprint
    }

    /// CRC-32 over the *serving schema* only (projection width/density,
    /// ensemble shape, score mode, dense feature names): absorb state is
    /// portable between ensembles exactly when this matches — the
    /// hot-reload carry-forward rule.
    pub fn schema_fingerprint(&self) -> u32 {
        self.schema_fingerprint
    }

    /// The dense feature names the model was trained against, if its
    /// projector carries a schema (used by `sparx serve` to synthesize a
    /// compatible demo stream; any names hash fine either way).
    pub fn feature_names(&self) -> Option<&[String]> {
        self.projector.dense_schema()
    }

    /// Resident bytes of the shared scoring state: trained chains (CMS
    /// blocks + chain params) plus the projector (hashers, memoised
    /// R\[D,K\], schema names). This is the footprint that is held
    /// **once** per process under Arc-sharing, regardless of the shard
    /// count.
    pub fn resident_bytes(&self) -> usize {
        use crate::util::SizeOf;
        self.chains.iter().map(SizeOf::size_of).sum::<usize>() + self.projector.resident_bytes()
    }

    /// Decide what a hot swap from `self` to `new` may carry forward:
    /// same fingerprint ⇒ everything ([`SwapCarry::Full`]); same serving
    /// schema ⇒ sketches and counters but not the absorbed delta
    /// ([`SwapCarry::SketchesOnly`]); different schema ⇒ typed rejection
    /// (the resident sketches would be meaningless under the new model).
    pub fn swap_carry(&self, new: &ServedEnsemble) -> Result<SwapCarry> {
        if self.model_fingerprint == new.model_fingerprint {
            return Ok(SwapCarry::Full);
        }
        if self.schema_fingerprint == new.schema_fingerprint {
            return Ok(SwapCarry::SketchesOnly);
        }
        Err(SparxError::Unsupported(format!(
            "cannot hot-swap to an ensemble with a different serving schema \
             (K={} L={} M={} r={} w={} vs K={} L={} M={} r={} w={}): absorbed \
             stream state is not portable across schemas",
            self.k,
            self.depth,
            self.chains.len(),
            self.cms_rows,
            self.cms_cols,
            new.k,
            new.depth,
            new.chains.len(),
            new.cms_rows,
            new.cms_cols,
        )))
    }
}

/// Sparse per-level overlay of absorbed CMS increments: the mutable
/// counterpart of the shared read-only counts. Indexed chain-major
/// (`m · L + l`), each level keyed by row-major bucket index.
#[derive(Debug, Clone)]
pub(crate) struct DeltaCms {
    pub(crate) levels: Vec<HashMap<u32, u32>>,
    depth: usize,
    /// Total overlay insertions recorded (never decremented); `0` means
    /// the fast no-overlay query path is exact.
    inserts: u64,
}

impl DeltaCms {
    fn new(num_chains: usize, depth: usize) -> DeltaCms {
        DeltaCms { levels: vec![HashMap::new(); num_chains * depth], depth, inserts: 0 }
    }

    fn chain_levels(&self, m: usize) -> &[HashMap<u32, u32>] {
        &self.levels[m * self.depth..(m + 1) * self.depth]
    }

    fn is_empty(&self) -> bool {
        self.inserts == 0
    }

    /// One half-life step: floor-halve every level, dropping zeroed
    /// entries, and recompute the emptiness indicator (a fully drained
    /// overlay re-enables the exact no-overlay query fast path).
    fn halve(&mut self) {
        for lvl in &mut self.levels {
            decay_halve_overlay(lvl);
        }
        self.inserts =
            self.levels.iter().map(|l| l.values().map(|&c| c as u64).sum::<u64>()).sum();
    }
}

/// Bin a sketch against every chain level and record the CMS increments
/// in `delta` — the shared insert loop behind the visible
/// ([`StreamScorer::absorb_only`]) and pending
/// ([`StreamScorer::absorb_pending`]) absorb paths.
fn absorb_sketch_into(
    ens: &ServedEnsemble,
    sketch: &[f32],
    scratch: &mut Vec<f32>,
    bins: &mut Vec<i32>,
    delta: &mut DeltaCms,
) {
    let k = ens.k;
    let depth = delta.depth;
    for (m, chain) in ens.chains.iter().enumerate() {
        chain.params.bins_into(sketch, scratch, bins);
        for (lvl, cms) in chain.cms.iter().enumerate() {
            cms.overlay_insert(&bins[lvl * k..(lvl + 1) * k], &mut delta.levels[m * depth + lvl]);
        }
    }
    delta.inserts += (ens.chains.len() * ens.depth * ens.cms_rows) as u64;
}

/// Overlay levels as sorted `(bucket, count)` vectors — the canonical
/// serialized form (deterministic regardless of hash-map iteration).
fn sorted_levels(levels: &[HashMap<u32, u32>]) -> Vec<Vec<(u32, u32)>> {
    levels
        .iter()
        .map(|lvl| {
            let mut v: Vec<(u32, u32)> = lvl.iter().map(|(&b, &c)| (b, c)).collect();
            v.sort_unstable();
            v
        })
        .collect()
}

/// The deployment-node scorer: one `Arc` handle on the shared
/// [`ServedEnsemble`] plus this scorer's own mutable absorb state (LRU
/// sketches + absorbed CMS delta + counters + scratch).
pub struct StreamScorer {
    ensemble: Arc<ServedEnsemble>,
    cache: LruCache<u64, Vec<f32>>,
    delta: DeltaCms,
    /// Absorbed-but-not-yet-visible increments (the sharded serving
    /// plane's epoch buffer): [`absorb_pending`](Self::absorb_pending)
    /// writes here; scoring never reads it. An epoch merge drains every
    /// shard's pending ([`take_pending`](Self::take_pending)), sums the
    /// increments, and publishes the result to every shard's *visible*
    /// delta ([`apply_visible`](Self::apply_visible)) — which is what
    /// makes absorb-mode scores independent of the shard count.
    pending: DeltaCms,
    /// The rotated-out previous window block (sliding-window scoring):
    /// empty unless a window rotation ([`rotate_window`](Self::rotate_window))
    /// has run. Scoring reads `base + delta + prev`, so absorbed mass
    /// survives exactly one rotation before dropping out.
    prev: DeltaCms,
    // scratch buffers reused across updates (no allocation per update)
    scratch: Vec<f32>,
    bins: Vec<i32>,
    evicted: u64,
    processed: u64,
    absorbed: u64,
}

impl StreamScorer {
    /// Build from a fitted model with an LRU capacity of `cache_size` IDs.
    /// Requires a hashing projector (k > 0): evolving features need the
    /// hash-not-cash trick of Eq. (2)/(3).
    pub fn new(model: &SparxModel, cache_size: usize) -> Result<Self> {
        Self::from_ensemble(Arc::new(ServedEnsemble::new(model)?), cache_size)
    }

    /// Build from an already-frozen (possibly shared) ensemble — the
    /// constructor the sharded front-end uses, so S shards hold S `Arc`
    /// handles on **one** resident model.
    pub fn from_ensemble(ensemble: Arc<ServedEnsemble>, cache_size: usize) -> Result<Self> {
        if cache_size == 0 {
            return Err(SparxError::InvalidParams(
                "stream cache size must be ≥ 1 (it bounds the resident sketches)".into(),
            ));
        }
        let k = ensemble.k();
        let depth = ensemble.depth();
        let m = ensemble.num_chains();
        Ok(StreamScorer {
            cache: LruCache::new(cache_size),
            delta: DeltaCms::new(m, depth),
            pending: DeltaCms::new(m, depth),
            prev: DeltaCms::new(m, depth),
            scratch: vec![0.0; k],
            bins: vec![0; depth * k],
            evicted: 0,
            processed: 0,
            absorbed: 0,
            ensemble,
        })
    }

    /// The shared read-only half of this scorer's state.
    pub fn ensemble(&self) -> &Arc<ServedEnsemble> {
        &self.ensemble
    }

    /// Bytes of the shared ensemble this scorer holds a handle on (not
    /// duplicated per scorer — see [`ServedEnsemble::resident_bytes`]).
    pub fn resident_ensemble_bytes(&self) -> usize {
        self.ensemble.resident_bytes()
    }

    /// Apply one ⟨ID, F, δ⟩ update (Eq. 3) and return the updated score.
    pub fn update(&mut self, u: &UpdateTriple) -> StreamScore {
        self.processed += 1;
        let id = u.id();
        let k = self.ensemble.k();
        let fresh = !self.cache.contains(&id);
        if fresh && self.cache.put(id, vec![0.0f32; k]).is_some() {
            self.evicted += 1;
        }
        {
            let s = self.cache.get_mut(&id).expect("just inserted");
            match u {
                UpdateTriple::Num { feature, delta, .. } => {
                    // s[k] += h_k(F) · δ — works for brand-new features too
                    for (sk, h) in s.iter_mut().zip(&self.ensemble.projector.hashers) {
                        *sk += h.feature(feature) * *delta as f32;
                    }
                }
                UpdateTriple::Cat { feature, old, new, .. } => {
                    // s[k] += h_k(F⊕new) − h_k(F⊕old); old = null ⇒ 0
                    for (sk, h) in s.iter_mut().zip(&self.ensemble.projector.hashers) {
                        *sk += h.feature_value(feature, new);
                        if let Some(o) = old {
                            *sk -= h.feature_value(feature, o);
                        }
                    }
                }
            }
        }
        let outlierness = self.score_id(id).expect("cached");
        StreamScore { id, outlierness, fresh }
    }

    /// Score a cached ID against the ensemble: O(rLM) CMS reads, zero
    /// allocations (scratch buffers are reused across updates). Uses the
    /// same [`score_bins`] kernel as the distributed and fused scorers,
    /// overlaying this scorer's absorbed delta when it is non-empty.
    pub fn score_id(&mut self, id: u64) -> Option<f64> {
        let s = self.cache.get(&id)?; // disjoint field borrows below
        let ens = &*self.ensemble;
        let overlay = !self.delta.is_empty();
        let windowed = !self.prev.is_empty();
        let mut total = 0.0;
        for (m, chain) in ens.chains.iter().enumerate() {
            chain.params.bins_into(s, &mut self.scratch, &mut self.bins);
            total += if windowed {
                score_bins_overlaid2(
                    chain,
                    ens.mode,
                    &self.bins,
                    self.delta.chain_levels(m),
                    self.prev.chain_levels(m),
                )
            } else if overlay {
                score_bins_overlaid(chain, ens.mode, &self.bins, self.delta.chain_levels(m))
            } else {
                score_bins(chain, ens.mode, &self.bins)
            };
        }
        Some(-(total / ens.chains.len() as f64))
    }

    /// Score a cached ID against the ensemble with a **caller-supplied**
    /// overlay instead of this scorer's own delta — the named-query read
    /// path, where each `(half_life, window)` query owns its view of the
    /// published increments. `levels` is chain-major (`m · L + l`) and
    /// must span exactly M·L levels; `None` if the ID is uncached or the
    /// shape disagrees.
    pub(crate) fn score_id_with(
        &mut self,
        id: u64,
        levels: &[HashMap<u32, u32>],
    ) -> Option<f64> {
        let s = self.cache.get(&id)?;
        let ens = &*self.ensemble;
        let depth = ens.depth;
        if levels.len() != ens.chains.len() * depth {
            return None;
        }
        let mut total = 0.0;
        for (m, chain) in ens.chains.iter().enumerate() {
            chain.params.bins_into(s, &mut self.scratch, &mut self.bins);
            let chain_levels = levels.get(m * depth..(m + 1) * depth)?;
            total += score_bins_overlaid(chain, ens.mode, &self.bins, chain_levels);
        }
        Some(-(total / ens.chains.len() as f64))
    }

    /// Absorb the point's current sketch into the density counts (the
    /// xStream streaming behaviour: new points update the histograms) and
    /// return its **post-absorb** score, so callers never pay a second
    /// `score_id` round. The increments land in this scorer's private
    /// delta overlay — the shared ensemble is never written.
    /// Returns `None` if the ID is not cached.
    pub fn absorb(&mut self, id: u64) -> Option<f64> {
        if !self.absorb_only(id) {
            return None;
        }
        self.score_id(id)
    }

    /// The insert half of [`absorb`](Self::absorb), without the rescore —
    /// immediate visibility (the next score of any nearby point sees the
    /// increment), which is the single-scorer streaming behaviour.
    pub(crate) fn absorb_only(&mut self, id: u64) -> bool {
        let Some(s) = self.cache.get(&id).cloned() else { return false };
        absorb_sketch_into(&self.ensemble, &s, &mut self.scratch, &mut self.bins, &mut self.delta);
        self.absorbed += 1;
        true
    }

    /// Absorb into the **pending** overlay instead: the increment stays
    /// invisible to scoring until an epoch merge republishes it through
    /// [`apply_visible`](Self::apply_visible). The sharded serving plane
    /// uses this so that what a score "has seen" is a function of the
    /// submit sequence alone, never of the shard layout.
    pub(crate) fn absorb_pending(&mut self, id: u64) -> bool {
        let Some(s) = self.cache.get(&id).cloned() else { return false };
        absorb_sketch_into(
            &self.ensemble,
            &s,
            &mut self.scratch,
            &mut self.bins,
            &mut self.pending,
        );
        self.absorbed += 1;
        true
    }

    /// Explicitly evict `id` from the sketch cache. The sharded serving
    /// plane drives eviction from a *global* recency directory (the
    /// per-shard caches are sized so they never self-evict); an explicit
    /// evict counts toward [`evictions`](Self::evictions) exactly like
    /// an LRU one.
    pub(crate) fn evict(&mut self, id: u64) -> bool {
        // remove() hands the sketch back (and we drop it here): the value
        // leaves memory at eviction time, not at some later slot reuse
        if self.cache.remove(&id).is_some() {
            self.evicted += 1;
            true
        } else {
            false
        }
    }

    /// One window rotation on the logical clock: the live absorbed delta
    /// becomes the previous block, the old previous block is dropped.
    /// Scoring covers `base + delta + prev`, so after a rotation the
    /// absorbed mass from two windows ago stops counting — the paired
    /// rotating blocks form of a sliding window.
    pub(crate) fn rotate_window(&mut self) {
        let m = self.ensemble.num_chains();
        let depth = self.ensemble.depth();
        self.prev = std::mem::replace(&mut self.delta, DeltaCms::new(m, depth));
    }

    /// One half-life step on the logical clock: floor-halve the visible
    /// delta **and** the previous window block (both carry absorbed mass
    /// that must decay). The pending epoch buffer is never halved — it
    /// holds increments submitted *after* the boundary forced its drain.
    pub(crate) fn decay_halve(&mut self) {
        self.delta.halve();
        self.prev.halve();
    }

    /// Drain the pending overlay for an epoch merge. Returns the raw
    /// per-level increment maps; the caller sums them across shards and
    /// publishes the total via [`apply_visible`](Self::apply_visible).
    pub(crate) fn take_pending(&mut self) -> Vec<HashMap<u32, u32>> {
        let drained = std::mem::replace(
            &mut self.pending,
            DeltaCms::new(self.ensemble.num_chains(), self.ensemble.depth()),
        );
        drained.levels
    }

    /// Publish merged epoch increments (sorted `(bucket, count)` pairs
    /// per level, chain-major) into the **visible** overlay. Addition of
    /// saturating integer counts is order-independent, so every shard
    /// ends up with the bit-identical visible state no matter how the
    /// per-shard pendings were interleaved.
    pub(crate) fn apply_visible(&mut self, levels: &[Vec<(u32, u32)>]) {
        for (slot, lvl) in levels.iter().enumerate() {
            if slot >= self.delta.levels.len() {
                break;
            }
            for &(bucket, count) in lvl {
                let c = self.delta.levels[slot].entry(bucket).or_insert(0);
                *c = c.saturating_add(count);
                self.delta.inserts += count as u64;
            }
        }
    }

    /// Sorted snapshot of the pending overlay (without draining it) —
    /// what a mid-epoch checkpoint persists so resume can hand the
    /// not-yet-merged increments back to the pool.
    pub(crate) fn pending_sorted(&self) -> Vec<Vec<(u32, u32)>> {
        sorted_levels(&self.pending.levels)
    }

    /// Restore a pending overlay persisted by a mid-epoch checkpoint.
    /// Validates like [`restore`](Self::restore).
    pub(crate) fn restore_pending(&mut self, levels: &[Vec<(u32, u32)>]) -> Result<()> {
        self.pending = self.decode_overlay("pending", levels)?;
        Ok(())
    }

    /// Restore the previous window block persisted by a checkpoint taken
    /// with `--window` active. Validates like [`restore`](Self::restore).
    pub(crate) fn restore_prev(&mut self, levels: &[Vec<(u32, u32)>]) -> Result<()> {
        self.prev = self.decode_overlay("prev-window", levels)?;
        Ok(())
    }

    /// Sorted snapshot of the previous window block (what the feeder
    /// persists for its master copy; see [`pending_sorted`](Self::pending_sorted)).
    pub(crate) fn prev_sorted(&self) -> Vec<Vec<(u32, u32)>> {
        sorted_levels(&self.prev.levels)
    }

    /// Shared validation + decode for a serialized overlay (`(bucket,
    /// count)` pairs per level, chain-major).
    fn decode_overlay(&self, what: &str, levels: &[Vec<(u32, u32)>]) -> Result<DeltaCms> {
        let ens = &*self.ensemble;
        let buckets = (ens.cms_rows * ens.cms_cols) as u32;
        if levels.len() != ens.chains.len() * ens.depth {
            return Err(SparxError::InvalidParams(format!(
                "{what} delta has {} levels for an M={} L={} ensemble",
                levels.len(),
                ens.chains.len(),
                ens.depth
            )));
        }
        let mut delta = DeltaCms::new(ens.chains.len(), ens.depth);
        for (slot, lvl) in levels.iter().enumerate() {
            for &(bucket, count) in lvl {
                if bucket >= buckets || count == 0 {
                    return Err(SparxError::InvalidParams(format!(
                        "{what} delta entry (bucket {bucket}, count {count}) is out of \
                         range for a {}×{} CMS",
                        ens.cms_rows, ens.cms_cols
                    )));
                }
                delta.levels[slot].insert(bucket, count);
                delta.inserts += count as u64;
            }
        }
        Ok(delta)
    }

    /// Serialize this scorer's mutable state (sketches in LRU→MRU order,
    /// absorbed delta, counters) — the unit the serving checkpoint merges
    /// across shards. The shared ensemble is *not* part of the snapshot;
    /// only its fingerprints travel, in the checkpoint header.
    pub fn snapshot(&self) -> AbsorbSnapshot {
        AbsorbSnapshot {
            processed: self.processed,
            evicted: self.evicted,
            absorbed: self.absorbed,
            entries: self.cache.iter_lru_to_mru().map(|(id, sk)| (*id, sk.clone())).collect(),
            delta: sorted_levels(&self.delta.levels),
        }
    }

    /// Snapshot variant for the sharded serving plane: the `delta` field
    /// carries the **pending** (not-yet-merged) overlay instead of the
    /// visible one. The visible overlay is identical on every shard, so
    /// the pool keeps one master copy feeder-side and persists that —
    /// per-shard snapshots only need what is genuinely per-shard.
    pub(crate) fn snapshot_with_pending(&self) -> AbsorbSnapshot {
        AbsorbSnapshot {
            processed: self.processed,
            evicted: self.evicted,
            absorbed: self.absorbed,
            entries: self.cache.iter_lru_to_mru().map(|(id, sk)| (*id, sk.clone())).collect(),
            delta: sorted_levels(&self.pending.levels),
        }
    }

    /// Restore a snapshot taken by [`snapshot`](Self::snapshot) against
    /// the **same** ensemble schema: the scorer continues bit-identically
    /// to the one the snapshot was taken from. Shape mismatches (sketch
    /// width, delta level count, bucket range, more entries than the
    /// cache holds) fail typed without touching the current state.
    pub fn restore(&mut self, snap: &AbsorbSnapshot) -> Result<()> {
        let ens = &*self.ensemble;
        let buckets = (ens.cms_rows * ens.cms_cols) as u32;
        if snap.delta.len() != ens.chains.len() * ens.depth {
            return Err(SparxError::InvalidParams(format!(
                "absorb snapshot has {} delta levels for an M={} L={} ensemble",
                snap.delta.len(),
                ens.chains.len(),
                ens.depth
            )));
        }
        if snap.entries.len() > self.cache.capacity() {
            return Err(SparxError::InvalidParams(format!(
                "absorb snapshot holds {} sketches but the cache capacity is {}",
                snap.entries.len(),
                self.cache.capacity()
            )));
        }
        for (id, sk) in &snap.entries {
            if sk.len() != ens.k {
                return Err(SparxError::InvalidParams(format!(
                    "absorb snapshot sketch for id {id} is {}-wide, ensemble expects K={}",
                    sk.len(),
                    ens.k
                )));
            }
        }
        for lvl in &snap.delta {
            for &(bucket, count) in lvl {
                if bucket >= buckets || count == 0 {
                    return Err(SparxError::InvalidParams(format!(
                        "absorb snapshot delta entry (bucket {bucket}, count {count}) is out \
                         of range for a {}×{} CMS",
                        ens.cms_rows, ens.cms_cols
                    )));
                }
            }
        }
        let mut cache = LruCache::new(self.cache.capacity());
        for (id, sk) in &snap.entries {
            cache.put(*id, sk.clone());
        }
        let mut delta = DeltaCms::new(ens.chains.len(), ens.depth);
        for (slot, lvl) in snap.delta.iter().enumerate() {
            for &(bucket, count) in lvl {
                delta.levels[slot].insert(bucket, count);
                delta.inserts += count as u64;
            }
        }
        self.cache = cache;
        self.delta = delta;
        self.pending = DeltaCms::new(ens.chains.len(), ens.depth);
        self.prev = DeltaCms::new(ens.chains.len(), ens.depth);
        self.processed = snap.processed;
        self.evicted = snap.evicted;
        self.absorbed = snap.absorbed;
        Ok(())
    }

    /// Atomically swap the served model (hot reload): the absorb state
    /// carries forward per [`ServedEnsemble::swap_carry`] — fully when
    /// the fingerprint matches, sketches-only when just the schema does —
    /// and a schema mismatch is rejected typed with no state change.
    pub fn swap_ensemble(&mut self, new: Arc<ServedEnsemble>) -> Result<SwapCarry> {
        let carry = self.ensemble.swap_carry(&new)?;
        if carry == SwapCarry::SketchesOnly {
            self.delta = DeltaCms::new(new.num_chains(), new.depth());
            self.pending = DeltaCms::new(new.num_chains(), new.depth());
            self.prev = DeltaCms::new(new.num_chains(), new.depth());
        }
        self.ensemble = new;
        Ok(carry)
    }

    pub fn cached_ids(&self) -> usize {
        self.cache.len()
    }

    pub fn evictions(&self) -> u64 {
        self.evicted
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Points absorbed into this scorer's delta overlay so far.
    pub fn absorbed(&self) -> u64 {
        self.absorbed
    }

    /// See [`ServedEnsemble::feature_names`].
    pub fn feature_names(&self) -> Option<&[String]> {
        self.ensemble.feature_names()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::data::generators::GisetteGen;
    use crate::sparx::SparxParams;

    fn fitted() -> SparxModel {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 400, d: 24, ..Default::default() }.generate(&ctx).unwrap();
        SparxModel::fit(
            &ctx,
            &ld.dataset,
            &SparxParams { k: 8, num_chains: 8, depth: 5, ..Default::default() },
        )
        .unwrap()
    }

    #[test]
    fn updates_accumulate() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 16).unwrap();
        let a = s.update(&UpdateTriple::Num { id: 1, feature: "f0".into(), delta: 1.0 });
        assert!(a.fresh);
        let b = s.update(&UpdateTriple::Num { id: 1, feature: "f0".into(), delta: 1.0 });
        assert!(!b.fresh);
        // two +1 updates must equal one +2 update on a fresh id
        let c2 = s.update(&UpdateTriple::Num { id: 2, feature: "f0".into(), delta: 2.0 });
        assert!((b.outlierness - c2.outlierness).abs() < 1e-9);
    }

    #[test]
    fn categorical_substitution_cancels() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 16).unwrap();
        let base = s.update(&UpdateTriple::Num { id: 5, feature: "f1".into(), delta: 0.7 });
        // NYC then NYC→Austin then Austin→NYC must return to the NYC state
        let _ = s.update(&UpdateTriple::Cat {
            id: 5,
            feature: "loc".into(),
            old: None,
            new: "NYC".into(),
        });
        let nyc1 = s.score_id(5).unwrap();
        let _ = s.update(&UpdateTriple::Cat {
            id: 5,
            feature: "loc".into(),
            old: Some("NYC".into()),
            new: "Austin".into(),
        });
        let _ = s.update(&UpdateTriple::Cat {
            id: 5,
            feature: "loc".into(),
            old: Some("Austin".into()),
            new: "NYC".into(),
        });
        let nyc2 = s.score_id(5).unwrap();
        assert!((nyc1 - nyc2).abs() < 1e-6, "{nyc1} vs {nyc2}");
        let _ = base;
    }

    #[test]
    fn brand_new_feature_accepted() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 16).unwrap();
        let r = s.update(&UpdateTriple::Num {
            id: 9,
            feature: "never_seen_indicator_42".into(),
            delta: 3.0,
        });
        assert!(r.outlierness.is_finite());
    }

    #[test]
    fn lru_bounds_memory() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 8).unwrap();
        for id in 0..100 {
            s.update(&UpdateTriple::Num { id, feature: "f0".into(), delta: 1.0 });
        }
        assert_eq!(s.cached_ids(), 8);
        assert_eq!(s.evictions(), 92);
        assert_eq!(s.processed(), 100);
    }

    /// Eviction starts exactly at `cache_size`: filling the cache costs
    /// nothing, the first id beyond it evicts.
    #[test]
    fn eviction_starts_exactly_at_cache_size() {
        let model = fitted();
        let cache_size = 6;
        let mut s = StreamScorer::new(&model, cache_size).unwrap();
        for id in 0..cache_size as u64 {
            s.update(&UpdateTriple::Num { id, feature: "f0".into(), delta: 1.0 });
        }
        assert_eq!(s.cached_ids(), cache_size);
        assert_eq!(s.evictions(), 0, "filling to capacity must not evict");
        s.update(&UpdateTriple::Num { id: 999, feature: "f0".into(), delta: 1.0 });
        assert_eq!(s.cached_ids(), cache_size);
        assert_eq!(s.evictions(), 1, "one past capacity evicts exactly one");
        assert_eq!(s.processed(), cache_size as u64 + 1);
    }

    /// An evicted id that comes back is `fresh` again and restarts from a
    /// zero sketch — its score equals the original first-update score,
    /// not the accumulated state from before eviction.
    #[test]
    fn readmission_after_eviction_is_fresh_with_reset_state() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 4).unwrap();
        let first = s.update(&UpdateTriple::Num { id: 0, feature: "f0".into(), delta: 1.0 });
        assert!(first.fresh);
        // accumulate more state on id 0, then push it out with 4 new ids
        let second = s.update(&UpdateTriple::Num { id: 0, feature: "f0".into(), delta: 1.0 });
        assert!(!second.fresh, "cached id must not be fresh");
        for id in 1..=4 {
            s.update(&UpdateTriple::Num { id, feature: "f0".into(), delta: 1.0 });
        }
        assert!(s.evictions() >= 1, "id 0 must have been evicted");
        assert!(s.score_id(0).is_none(), "evicted id has no cached sketch");
        let back = s.update(&UpdateTriple::Num { id: 0, feature: "f0".into(), delta: 1.0 });
        assert!(back.fresh, "re-admission after eviction must set fresh again");
        assert_eq!(
            back.outlierness, first.outlierness,
            "re-admitted sketch must restart from zero, not resume"
        );
        assert_eq!(s.processed(), 7);
    }

    #[test]
    fn absorb_increases_density_at_point_and_returns_the_post_absorb_score() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 16).unwrap();
        let before = s.update(&UpdateTriple::Num { id: 3, feature: "f2".into(), delta: 5.0 });
        // absorbing the point several times makes its region denser ⇒ its
        // outlierness must strictly drop
        let mut last = f64::INFINITY;
        for _ in 0..5 {
            last = s.absorb(3).expect("id 3 is cached");
        }
        assert_eq!(s.absorbed(), 5);
        assert!(last < before.outlierness, "{last} !< {}", before.outlierness);
        // the returned score is exactly what a rescore would produce
        assert_eq!(s.score_id(3).unwrap(), last, "absorb must return the post-absorb score");
        // absorbing an uncached id is a no-op signalled by None
        assert_eq!(s.absorb(123456), None);
        assert_eq!(s.absorbed(), 5);
    }

    /// The sharded plane's absorb path: a pending absorb must not move
    /// scores until published, and publishing the drained increments
    /// must land bit-identically to an immediate absorb.
    #[test]
    fn pending_absorb_is_invisible_until_published() {
        let model = fitted();
        let u = UpdateTriple::Num { id: 3, feature: "f2".into(), delta: 5.0 };
        let mut s = StreamScorer::new(&model, 16).unwrap();
        let before = s.update(&u);
        assert!(s.absorb_pending(3));
        assert_eq!(s.absorbed(), 1);
        assert_eq!(
            s.score_id(3).unwrap().to_bits(),
            before.outlierness.to_bits(),
            "pending absorb leaked into scoring before the epoch merge"
        );
        // reference: immediate absorb on an identical scorer
        let mut t = StreamScorer::new(&model, 16).unwrap();
        let _ = t.update(&u);
        t.absorb(3).unwrap();
        // publish the drained pending — must match the immediate path
        let drained = sorted_levels(&s.take_pending());
        s.apply_visible(&drained);
        assert_eq!(s.score_id(3).unwrap().to_bits(), t.score_id(3).unwrap().to_bits());
        assert!(s.take_pending().iter().all(|m| m.is_empty()), "take_pending must drain");
        // restore_pending round-trips and validates
        let mut r = StreamScorer::new(&model, 16).unwrap();
        let _ = r.update(&u);
        r.absorb_pending(3);
        let saved = r.pending_sorted();
        let mut fresh = StreamScorer::new(&model, 16).unwrap();
        fresh.restore_pending(&saved).unwrap();
        assert_eq!(fresh.pending_sorted(), saved);
        assert!(matches!(
            fresh.restore_pending(&[Vec::new()]),
            Err(SparxError::InvalidParams(_))
        ));
        // explicit evict removes the sketch and counts as an eviction
        assert!(s.evict(3));
        assert!(!s.evict(3), "double evict is a no-op");
        assert_eq!(s.evictions(), 1);
        assert!(s.score_id(3).is_none());
    }

    /// Paired rotating blocks: one rotation keeps absorbed mass visible
    /// (it moves to `prev`), a second drops it; floor-halving 2n absorbs
    /// equals n absorbs bit-for-bit.
    #[test]
    fn rotation_and_halving_follow_the_paired_block_semantics() {
        let model = fitted();
        let u = UpdateTriple::Num { id: 3, feature: "f2".into(), delta: 5.0 };
        let mut s = StreamScorer::new(&model, 16).unwrap();
        let base = s.update(&u);
        for _ in 0..4 {
            s.absorb(3).unwrap();
        }
        let absorbed = s.score_id(3).unwrap();
        assert!(absorbed < base.outlierness);
        s.rotate_window();
        assert_eq!(
            s.score_id(3).unwrap().to_bits(),
            absorbed.to_bits(),
            "after one rotation the mass lives in prev and still counts"
        );
        s.rotate_window();
        assert_eq!(
            s.score_id(3).unwrap().to_bits(),
            base.outlierness.to_bits(),
            "after two rotations the window has slid past the absorbed mass"
        );
        // halving 4 absorbs equals 2 absorbs exactly (integer floor)
        let mut a = StreamScorer::new(&model, 16).unwrap();
        a.update(&u);
        for _ in 0..4 {
            a.absorb(3).unwrap();
        }
        a.decay_halve();
        let mut b = StreamScorer::new(&model, 16).unwrap();
        b.update(&u);
        for _ in 0..2 {
            b.absorb(3).unwrap();
        }
        assert_eq!(a.score_id(3).unwrap().to_bits(), b.score_id(3).unwrap().to_bits());
        // halving also decays the rotated-out prev block
        a.rotate_window();
        a.decay_halve();
        let mut c = StreamScorer::new(&model, 16).unwrap();
        c.update(&u);
        c.absorb(3).unwrap();
        assert_eq!(a.score_id(3).unwrap().to_bits(), c.score_id(3).unwrap().to_bits());
        // prev round-trips through its serialized form
        let saved = a.prev_sorted();
        let mut r = StreamScorer::new(&model, 16).unwrap();
        r.update(&u);
        r.restore_prev(&saved).unwrap();
        assert_eq!(r.score_id(3).unwrap().to_bits(), a.score_id(3).unwrap().to_bits());
        assert!(matches!(r.restore_prev(&[Vec::new()]), Err(SparxError::InvalidParams(_))));
    }

    /// The named-query read path: a caller-supplied overlay scores
    /// exactly like the scorer's own published delta, and shape or cache
    /// misses answer `None`.
    #[test]
    fn score_id_with_reads_a_caller_supplied_overlay() {
        let model = fitted();
        let u = UpdateTriple::Num { id: 3, feature: "f2".into(), delta: 5.0 };
        let mut t = StreamScorer::new(&model, 16).unwrap();
        t.update(&u);
        for _ in 0..3 {
            t.absorb_pending(3);
        }
        let overlay = t.take_pending();
        t.apply_visible(&sorted_levels(&overlay));
        let want = t.score_id(3).unwrap();
        let mut s = StreamScorer::new(&model, 16).unwrap();
        s.update(&u);
        assert_eq!(s.score_id_with(3, &overlay).unwrap().to_bits(), want.to_bits());
        assert!(s.score_id_with(3, &overlay[..1]).is_none(), "wrong level count");
        assert!(s.score_id_with(999, &overlay).is_none(), "uncached id");
    }

    /// Two scorers sharing one `Arc<ServedEnsemble>`: absorbing on one
    /// must not move the other's scores by a bit — the shared base counts
    /// are read-only, deltas are private.
    #[test]
    fn absorb_is_private_to_the_scorer_under_a_shared_ensemble() {
        let model = fitted();
        let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
        let mut a = StreamScorer::from_ensemble(ens.clone(), 16).unwrap();
        let mut b = StreamScorer::from_ensemble(ens.clone(), 16).unwrap();
        let u = UpdateTriple::Num { id: 7, feature: "f1".into(), delta: 2.0 };
        let sa = a.update(&u);
        let sb = b.update(&u);
        assert_eq!(sa.outlierness.to_bits(), sb.outlierness.to_bits());
        for _ in 0..10 {
            a.absorb(7).unwrap();
        }
        assert_eq!(
            b.score_id(7).unwrap().to_bits(),
            sb.outlierness.to_bits(),
            "a sibling scorer's absorb must not leak through the shared ensemble"
        );
        assert!(a.score_id(7).unwrap() < sa.outlierness);
        assert_eq!(Arc::strong_count(&ens), 3, "one shared ensemble, three handles");
    }

    /// Snapshot → restore continues bit-identically, including LRU
    /// recency (eviction order) and the absorbed delta.
    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        let model = fitted();
        let ens = Arc::new(ServedEnsemble::new(&model).unwrap());
        let mut original = StreamScorer::from_ensemble(ens.clone(), 4).unwrap();
        for id in 0..6u64 {
            let s = original.update(&UpdateTriple::Num {
                id,
                feature: "f0".into(),
                delta: 0.5 + id as f64,
            });
            original.absorb(s.id);
        }
        let snap = original.snapshot();
        let mut restored = StreamScorer::from_ensemble(ens, 4).unwrap();
        restored.restore(&snap).unwrap();
        assert_eq!(restored.processed(), original.processed());
        assert_eq!(restored.evictions(), original.evictions());
        assert_eq!(restored.absorbed(), original.absorbed());
        assert_eq!(restored.cached_ids(), original.cached_ids());
        // identical continuation: same scores, same eviction behaviour
        for id in [3u64, 9, 4, 0, 11, 5] {
            let a = original.update(&UpdateTriple::Num { id, feature: "f1".into(), delta: 1.5 });
            let b = restored.update(&UpdateTriple::Num { id, feature: "f1".into(), delta: 1.5 });
            assert_eq!(a, b, "divergence at id {id}");
        }
        assert_eq!(original.evictions(), restored.evictions());
    }

    #[test]
    fn restore_rejects_mismatched_shapes_typed() {
        let model = fitted();
        let mut s = StreamScorer::new(&model, 4).unwrap();
        s.update(&UpdateTriple::Num { id: 1, feature: "f0".into(), delta: 1.0 });
        let good = s.snapshot();
        // wrong sketch width
        let mut bad = good.clone();
        bad.entries.push((99, vec![0.0; 3]));
        assert!(matches!(s.restore(&bad), Err(SparxError::InvalidParams(_))));
        // wrong delta level count
        let mut bad = good.clone();
        bad.delta.pop();
        assert!(matches!(s.restore(&bad), Err(SparxError::InvalidParams(_))));
        // more entries than the cache can hold
        let mut bad = good.clone();
        for id in 100..110u64 {
            bad.entries.push((id, vec![0.0; 8]));
        }
        assert!(matches!(s.restore(&bad), Err(SparxError::InvalidParams(_))));
        // bucket out of range
        let mut bad = good;
        bad.delta[0].push((u32::MAX, 1));
        assert!(matches!(s.restore(&bad), Err(SparxError::InvalidParams(_))));
        // the failed restores must not have clobbered the live state
        assert_eq!(s.processed(), 1);
    }

    /// Hot swap: same model carries everything; same schema but different
    /// chains carries the sketches and resets the delta; a different
    /// schema is rejected typed with no state change.
    #[test]
    fn swap_ensemble_carry_rules() {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 400, d: 24, ..Default::default() }.generate(&ctx).unwrap();
        let p = SparxParams { k: 8, num_chains: 8, depth: 5, ..Default::default() };
        let model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
        let retrained = SparxModel::fit(
            &ctx,
            &ld.dataset,
            &SparxParams { seed: 0xD1FF, ..p.clone() },
        )
        .unwrap();
        let other_schema =
            SparxModel::fit(&ctx, &ld.dataset, &SparxParams { k: 12, ..p.clone() }).unwrap();

        let mut s = StreamScorer::new(&model, 16).unwrap();
        let u = UpdateTriple::Num { id: 1, feature: "f0".into(), delta: 1.0 };
        let before = s.update(&u);
        s.absorb(1).unwrap();

        // same model → Full carry: nothing moves
        let same = Arc::new(ServedEnsemble::new(&model).unwrap());
        let with_delta = s.score_id(1).unwrap();
        assert_eq!(s.swap_ensemble(same).unwrap(), SwapCarry::Full);
        assert_eq!(s.score_id(1).unwrap().to_bits(), with_delta.to_bits());
        assert_eq!(s.processed(), 1);

        // schema match, different chains → sketches carry, delta resets
        let re = Arc::new(ServedEnsemble::new(&retrained).unwrap());
        assert_eq!(s.swap_ensemble(re.clone()).unwrap(), SwapCarry::SketchesOnly);
        assert_eq!(s.cached_ids(), 1, "sketches must survive a schema-compatible swap");
        let mut fresh = StreamScorer::from_ensemble(re, 16).unwrap();
        let fresh_score = fresh.update(&u);
        assert_eq!(
            s.score_id(1).unwrap().to_bits(),
            fresh_score.outlierness.to_bits(),
            "after a sketches-only swap the score must equal a fresh scorer's \
             (same sketch, no delta) under the new model"
        );

        // different schema → typed rejection, no state change
        let alien = Arc::new(ServedEnsemble::new(&other_schema).unwrap());
        let r = s.swap_ensemble(alien);
        assert!(matches!(r, Err(SparxError::Unsupported(_))), "{:?}", r.err());
        assert_eq!(s.cached_ids(), 1);
        let _ = before;
    }

    #[test]
    fn fingerprints_separate_model_schema_and_mode() {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = GisetteGen { n: 300, d: 16, ..Default::default() }.generate(&ctx).unwrap();
        let p = SparxParams { k: 8, num_chains: 6, depth: 4, ..Default::default() };
        let a = ServedEnsemble::new(&SparxModel::fit(&ctx, &ld.dataset, &p).unwrap()).unwrap();
        let b = ServedEnsemble::new(&SparxModel::fit(&ctx, &ld.dataset, &p).unwrap()).unwrap();
        assert_eq!(a.model_fingerprint(), b.model_fingerprint(), "same fit must fingerprint equal");
        let reseeded =
            SparxModel::fit(&ctx, &ld.dataset, &SparxParams { seed: 99, ..p.clone() }).unwrap();
        let c = ServedEnsemble::new(&reseeded).unwrap();
        assert_ne!(a.model_fingerprint(), c.model_fingerprint());
        assert_eq!(a.schema_fingerprint(), c.schema_fingerprint(), "same schema, new chains");
        let wider = SparxModel::fit(&ctx, &ld.dataset, &SparxParams { k: 9, ..p }).unwrap();
        let d = ServedEnsemble::new(&wider).unwrap();
        assert_ne!(a.schema_fingerprint(), d.schema_fingerprint());
    }

    #[test]
    fn zero_cache_size_is_a_typed_error_not_a_panic() {
        let model = fitted();
        assert!(matches!(
            StreamScorer::new(&model, 0),
            Err(crate::api::SparxError::InvalidParams(_))
        ));
    }

    #[test]
    fn identity_model_rejected() {
        let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
        let ld = crate::data::generators::OsmGen {
            n_inliers: 500,
            n_outliers: 5,
            roads: 5,
            cities: 3,
            ..Default::default()
        }
        .generate(&ctx)
        .unwrap();
        let model = SparxModel::fit(
            &ctx,
            &ld.dataset,
            &SparxParams { k: 0, num_chains: 4, depth: 4, ..Default::default() },
        )
        .unwrap();
        assert!(StreamScorer::new(&model, 8).is_err());
        assert!(ServedEnsemble::new(&model).is_err());
    }
}
