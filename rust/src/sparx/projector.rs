//! Step 1 — distributed data projection (Algorithm 1, Eq. 2).
//!
//! Each point is mapped to a K-dimensional sketch with the shared sparse
//! sign-hash family: numeric features contribute `h_k(name)·x[F]`,
//! categorical features `h_k(name ⊕ value)·1`. Projection is fully local
//! (a single map pass — no communication), which is the crux of the
//! paper's Step-1 scalability.
//!
//! Encodings:
//! * **Dense** rows use a per-worker memoised sign matrix R[D,K] (the
//!   paper's footnote 3: numeric feature names are hashed once) — this is
//!   also the exact operand fed to the AOT `project` artifact, so the
//!   PJRT matmul path and this one agree to float-order.
//! * **Sparse** rows hash only their non-zeros, with a worker-local memo
//!   keyed by column index (SpamURL: 3.2M columns but ~150 nnz/row).
//! * **Mixed** rows hash name or name⊕value per entry (evolving streams).

use std::sync::Arc;

use crate::cluster::{ClusterContext, DistVec, Result};
use crate::data::{Dataset, Features, Row, Value};
use crate::hash::SignHasher;
use crate::util::SizeOf;

/// A K-dim sketch row: the id travels with the point through the pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct Sketch {
    pub id: u64,
    pub s: Vec<f32>,
}

impl SizeOf for Sketch {
    fn size_of(&self) -> usize {
        8 + std::mem::size_of::<Vec<f32>>() + self.s.len() * 4
    }
}

/// The shared projector: same seeds on every worker (Alg. 1 line 1).
#[derive(Debug, Clone)]
pub struct Projector {
    pub hashers: Vec<SignHasher>,
    /// Dense-schema sign matrix R[D,K], memoised once per job.
    dense_r: Option<Arc<Vec<f32>>>,
    /// The feature names R was materialised from — kept so a serialized
    /// model can rebuild the identical matrix at load time (the artifact
    /// stores names, not the O(D·K) matrix).
    schema_names: Option<Arc<Vec<String>>>,
    dim: usize,
}

impl Projector {
    /// `k` projections at `density` (paper: 1/3), seeds `0..k`.
    pub fn new(k: usize, density: f64) -> Self {
        Projector {
            hashers: SignHasher::family(k, density),
            dense_r: None,
            schema_names: None,
            dim: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.hashers.len()
    }

    /// The sign-hash density shared by the family (undefined for the
    /// identity projector, which has no hashers).
    pub fn density(&self) -> Option<f64> {
        self.hashers.first().map(|h| h.density())
    }

    /// Precompute R for a dense schema (also used to feed the PJRT
    /// projection artifact).
    pub fn with_dense_schema(mut self, feature_names: &[String]) -> Self {
        self.dim = feature_names.len();
        self.dense_r = Some(Arc::new(crate::hash::sign::materialize_r(
            feature_names,
            &self.hashers,
        )));
        self.schema_names = Some(Arc::new(feature_names.to_vec()));
        self
    }

    /// The materialised R[D,K] (row-major by feature), if dense.
    pub fn dense_r(&self) -> Option<&[f32]> {
        self.dense_r.as_deref().map(|v| v.as_slice())
    }

    /// The feature names the dense matrix was built from, if any.
    pub fn dense_schema(&self) -> Option<&[String]> {
        self.schema_names.as_deref().map(|v| v.as_slice())
    }

    /// The input width this projector requires of **dense** rows: the
    /// identity passes raw features through (width must match what the
    /// chains were fit on), and a materialised R[D,K] indexes rows by
    /// position. `None` means any width — the projector hashes feature
    /// names on the fly (sparse/mixed rows, or no dense schema).
    pub fn expected_dense_dim(&self) -> Option<usize> {
        if self.is_identity() {
            if self.dim > 0 {
                Some(self.dim)
            } else {
                None
            }
        } else {
            self.schema_names.as_ref().map(|n| n.len())
        }
    }

    /// Project one row (Eq. 2). `memo` is an optional worker-local cache
    /// of hash rows for sparse columns.
    pub fn project(
        &self,
        row: &Row,
        memo: Option<&mut std::collections::HashMap<u32, Vec<f32>>>,
    ) -> Sketch {
        let k = self.k();
        let mut s = vec![0f32; k];
        match &row.features {
            Features::Dense(x) => {
                let r = self
                    .dense_r
                    .as_ref()
                    .expect("dense rows require with_dense_schema()");
                debug_assert_eq!(x.len() * k, r.len(), "schema/row dim mismatch");
                for (j, &xj) in x.iter().enumerate() {
                    if xj == 0.0 {
                        continue;
                    }
                    let rj = &r[j * k..(j + 1) * k];
                    for (sk, &rk) in s.iter_mut().zip(rj) {
                        *sk += rk * xj;
                    }
                }
            }
            Features::Sparse { idx, val } => {
                let mut local = std::collections::HashMap::new();
                let memo = match memo {
                    Some(m) => m,
                    None => &mut local,
                };
                let mut name_buf = String::with_capacity(12);
                for (&j, &xj) in idx.iter().zip(val) {
                    if xj == 0.0 {
                        continue;
                    }
                    let hrow = memo.entry(j).or_insert_with(|| {
                        use std::fmt::Write;
                        name_buf.clear();
                        let _ = write!(name_buf, "f{j}");
                        self.hashers.iter().map(|h| h.feature(&name_buf)).collect()
                    });
                    for (sk, &rk) in s.iter_mut().zip(hrow.iter()) {
                        *sk += rk * xj;
                    }
                }
            }
            Features::Mixed(pairs) => {
                for (name, value) in pairs {
                    match value {
                        Value::Num(x) => {
                            if *x == 0.0 {
                                continue;
                            }
                            for (sk, h) in s.iter_mut().zip(&self.hashers) {
                                *sk += h.feature(name) * *x as f32;
                            }
                        }
                        Value::Cat(v) => {
                            for (sk, h) in s.iter_mut().zip(&self.hashers) {
                                *sk += h.feature_value(name, v);
                            }
                        }
                    }
                }
            }
        }
        Sketch { id: row.id, s }
    }

    /// Identity "projection" for already-low-dimensional data (the paper
    /// does not transform OSM): sketch = raw dense features.
    pub fn identity(dim: usize) -> Self {
        Projector { hashers: Vec::new(), dense_r: None, schema_names: None, dim }
    }

    pub fn is_identity(&self) -> bool {
        self.hashers.is_empty()
    }

    pub fn out_dim(&self) -> usize {
        if self.is_identity() {
            self.dim
        } else {
            self.k()
        }
    }

    /// Resident bytes of this projector: hashers, the memoised R\[D,K\]
    /// sign matrix and the schema names. Used to account the shared
    /// serving ensemble's footprint (`ServedEnsemble::resident_bytes`).
    /// The R matrix and names live behind `Arc`s, so clones of one
    /// projector share them — this reports the one resident copy.
    pub fn resident_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.hashers.len() * std::mem::size_of::<crate::hash::SignHasher>()
            + self.dense_r.as_ref().map_or(0, |r| r.len() * 4)
            + self.schema_names.as_ref().map_or(0, |names| {
                names.iter().map(|n| n.len() + std::mem::size_of::<String>()).sum()
            })
    }
}

/// Step 1 as a distributed job: one map pass, no shuffles.
pub fn project_dataset(
    ctx: &ClusterContext,
    data: &Dataset,
    projector: &Projector,
) -> Result<DistVec<Sketch>> {
    if projector.is_identity() {
        return data.rows.map(ctx, |row| Sketch {
            id: row.id,
            s: row.features.as_dense().to_vec(),
        });
    }
    data.rows.map_partitions(ctx, |_, part| {
        // worker-local sparse-column memo, shared within the partition
        let mut memo = std::collections::HashMap::new();
        Ok(part.iter().map(|row| projector.project(row, Some(&mut memo))).collect())
    })
}

/// Distributed Δ computation: half the min-max range of each projected
/// feature (local min/max per worker, constant-size partials combined on
/// the driver). Zero ranges clamp to a small width so Eq. (4) stays
/// well-defined.
pub fn compute_deltamax(ctx: &ClusterContext, proj: &DistVec<Sketch>) -> Result<Vec<f32>> {
    let k = match (0..proj.num_parts()).find(|&p| !proj.part(p).is_empty()) {
        Some(p) => proj.part(p)[0].s.len(),
        None => return Ok(Vec::new()),
    };
    let init = (vec![f32::INFINITY; k], vec![f32::NEG_INFINITY; k]);
    let (lo, hi) = proj.aggregate(
        ctx,
        init,
        |(mut lo, mut hi), sk| {
            for j in 0..k {
                lo[j] = lo[j].min(sk.s[j]);
                hi[j] = hi[j].max(sk.s[j]);
            }
            (lo, hi)
        },
        |(mut lo, mut hi), (lo2, hi2)| {
            for j in 0..k {
                lo[j] = lo[j].min(lo2[j]);
                hi[j] = hi[j].max(hi2[j]);
            }
            (lo, hi)
        },
    )?;
    Ok(lo
        .iter()
        .zip(&hi)
        .map(|(&l, &h)| {
            let d = (h - l) / 2.0;
            if d.is_finite() && d > 1e-12 {
                d
            } else {
                0.5
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::data::Schema;

    fn ctx() -> ClusterContext {
        ClusterConfig { num_partitions: 3, ..Default::default() }.build()
    }

    #[test]
    fn dense_equals_sparse_encoding() {
        // the same point encoded densely and sparsely must sketch equally
        let names: Vec<String> = (0..8).map(|j| format!("f{j}")).collect();
        let p = Projector::new(5, 1.0 / 3.0).with_dense_schema(&names);
        let dense = Row::dense(0, vec![0., 2., 0., 0., -1.5, 0., 0., 3.]);
        let sparse = Row::sparse(0, vec![1, 4, 7], vec![2.0, -1.5, 3.0]);
        let a = p.project(&dense, None);
        let b = p.project(&sparse, None);
        for (x, y) in a.s.iter().zip(&b.s) {
            assert!((x - y).abs() < 1e-5, "{:?} vs {:?}", a.s, b.s);
        }
    }

    #[test]
    fn mixed_numeric_matches_dense() {
        let names: Vec<String> = (0..3).map(|j| format!("f{j}")).collect();
        let p = Projector::new(4, 1.0 / 3.0).with_dense_schema(&names);
        let dense = Row::dense(0, vec![1.0, 0.0, -2.0]);
        let mixed = Row::mixed(
            0,
            vec![
                ("f0".into(), Value::Num(1.0)),
                ("f2".into(), Value::Num(-2.0)),
            ],
        );
        let a = p.project(&dense, None);
        let b = p.project(&mixed, None);
        for (x, y) in a.s.iter().zip(&b.s) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn categorical_contributes_unit_weight() {
        let p = Projector::new(16, 1.0);
        // density 1 → every hash is ±1 → each categorical adds ±1 per k
        let row = Row::mixed(0, vec![("loc".into(), Value::Cat("NYC".into()))]);
        let sk = p.project(&row, None);
        assert!(sk.s.iter().all(|&v| v == 1.0 || v == -1.0));
        // different category value must flip at least one sign
        let row2 = Row::mixed(0, vec![("loc".into(), Value::Cat("Austin".into()))]);
        let sk2 = p.project(&row2, None);
        assert_ne!(sk.s, sk2.s);
    }

    #[test]
    fn distance_preservation_on_average() {
        // Johnson-Lindenstrauss-ish sanity: sketch distances correlate
        // with original distances across many pairs.
        let d = 64;
        let names: Vec<String> = (0..d).map(|j| format!("f{j}")).collect();
        let p = Projector::new(32, 1.0 / 3.0).with_dense_schema(&names);
        let mut rng = crate::util::Rng::new(13);
        let pts: Vec<Row> = (0..40)
            .map(|i| Row::dense(i, (0..d).map(|_| rng.normal() as f32).collect()))
            .collect();
        let sks: Vec<Sketch> = pts.iter().map(|r| p.project(r, None)).collect();
        let mut num = 0.0;
        let mut den_a = 0.0;
        let mut den_b = 0.0;
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
        };
        let mut orig_d = Vec::new();
        let mut sk_d = Vec::new();
        for i in 0..pts.len() {
            for j in (i + 1)..pts.len() {
                orig_d.push(dist(pts[i].features.as_dense(), pts[j].features.as_dense()));
                sk_d.push(dist(&sks[i].s, &sks[j].s));
            }
        }
        let mo = orig_d.iter().sum::<f64>() / orig_d.len() as f64;
        let ms = sk_d.iter().sum::<f64>() / sk_d.len() as f64;
        for (o, s) in orig_d.iter().zip(&sk_d) {
            num += (o - mo) * (s - ms);
            den_a += (o - mo) * (o - mo);
            den_b += (s - ms) * (s - ms);
        }
        let corr = num / (den_a.sqrt() * den_b.sqrt());
        assert!(corr > 0.5, "projection destroys geometry: corr={corr}");
    }

    #[test]
    fn project_dataset_single_pass_no_shuffle() {
        let c = ctx();
        let rows = DistVec::from_vec(
            &c,
            (0..30).map(|i| Row::dense(i, vec![i as f32, 1.0])).collect(),
        )
        .unwrap();
        let ds = Dataset::new(Schema::positional(2), rows);
        let p = Projector::new(4, 1.0 / 3.0).with_dense_schema(&ds.schema.names);
        let before = c.ledger.bytes();
        let proj = project_dataset(&c, &ds, &p).unwrap();
        assert_eq!(proj.len(), 30);
        assert_eq!(c.ledger.bytes(), before, "Step 1 must not shuffle");
    }

    #[test]
    fn deltamax_matches_half_range() {
        let c = ctx();
        let sketches: Vec<Sketch> = vec![
            Sketch { id: 0, s: vec![-1.0, 10.0] },
            Sketch { id: 1, s: vec![3.0, 10.0] },
            Sketch { id: 2, s: vec![1.0, 10.0] },
        ];
        let dv = DistVec::from_vec(&c, sketches).unwrap();
        let delta = compute_deltamax(&c, &dv).unwrap();
        assert!((delta[0] - 2.0).abs() < 1e-6);
        // constant feature → clamped fallback width
        assert_eq!(delta[1], 0.5);
    }

    #[test]
    fn identity_projection_passthrough() {
        let c = ctx();
        let rows =
            DistVec::from_vec(&c, vec![Row::dense(0, vec![5.0, -3.0])]).unwrap();
        let ds = Dataset::new(Schema::positional(2), rows);
        let p = Projector::identity(2);
        let proj = project_dataset(&c, &ds, &p).unwrap();
        assert_eq!(proj.collect(&c).unwrap()[0].s, vec![5.0, -3.0]);
    }
}
