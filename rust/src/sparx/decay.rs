//! Logical-clock decay schedules and named-query state for the
//! streaming plane (the ROADMAP's "windowed and multi-query streaming
//! semantics" item).
//!
//! Everything here is keyed off the **global submit sequence** — the
//! logical clock the feeder already assigns to every update — never
//! wall time. A decay boundary is therefore a pure function of how many
//! updates were submitted, which is what keeps the decayed/windowed
//! score sequence bit-identical to `--shards 1` at any shard count and
//! across a kill → `--resume` cut (the persisted `submitted` counter
//! resumes the schedule mid-period with no drift).
//!
//! Two mechanisms compose (either or both may be active):
//!
//! * **exponential count decay** — every `half_life` submits the
//!   absorbed overlays are floor-halved ([`decay_halve_overlay`]),
//!   dropping zeroed entries;
//! * **sliding window via paired rotating blocks** — every `window`
//!   submits the live absorb block rotates into a `prev` block and the
//!   old `prev` is dropped, so scoring (base + cur + prev) covers at
//!   most the last two window periods of absorbed mass.
//!
//! [`QueryState`] reuses the same two mechanisms for the multi-query
//! serving layer: each named `(half_life, window)` configuration
//! accumulates the *published* epoch increments under its own schedule,
//! evaluated over the single shared ingest stream.

use std::collections::HashMap;

use crate::api::{Result, SparxError};

use super::cms::decay_halve_overlay;

/// Longest accepted query name (also bounds checkpoint decode).
pub const MAX_QUERY_NAME: usize = 64;

/// Most named queries a scorer will hold at once.
pub const MAX_QUERIES: usize = 64;

/// A decay/window schedule on the logical clock. `0` disables the
/// respective mechanism; the default is fully disabled (the undecayed,
/// accumulate-forever behaviour of earlier revisions).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DecaySpec {
    /// Floor-halve the absorbed overlays every `half_life` submits.
    pub half_life: u64,
    /// Rotate the live absorb block to `prev` every `window` submits.
    pub window: u64,
}

impl DecaySpec {
    pub fn new(half_life: u64, window: u64) -> DecaySpec {
        DecaySpec { half_life, window }
    }

    /// Whether any decay mechanism is active.
    pub fn enabled(&self) -> bool {
        self.half_life > 0 || self.window > 0
    }

    /// Whether a window rotation falls due at this submit count.
    pub fn rotate_due(&self, submitted: u64) -> bool {
        self.window > 0 && submitted > 0 && submitted % self.window == 0
    }

    /// Whether a half-life floor-halving falls due at this submit count.
    pub fn halve_due(&self, submitted: u64) -> bool {
        self.half_life > 0 && submitted > 0 && submitted % self.half_life == 0
    }
}

/// Validate a wire/CLI query name: one token, 1–64 bytes of
/// `[A-Za-z0-9._-]`. The charset guarantees the name round-trips
/// through the whitespace-tokenized wire grammar and the checkpoint
/// codec without escaping.
pub fn validate_query_name(name: &str) -> Result<()> {
    if name.is_empty() || name.len() > MAX_QUERY_NAME {
        return Err(SparxError::InvalidParams(format!(
            "query name must be 1–{MAX_QUERY_NAME} bytes, got {} bytes",
            name.len()
        )));
    }
    if let Some(c) =
        name.chars().find(|c| !(c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')))
    {
        return Err(SparxError::InvalidParams(format!(
            "query name {name:?} contains {c:?}; allowed characters are [A-Za-z0-9._-]"
        )));
    }
    Ok(())
}

/// One named `(half_life, window)` view over the shared ingest stream.
///
/// Lives feeder-side in the sharded scorer: every published epoch
/// increment is added to `cur` ([`on_publish`](Self::on_publish)), and
/// the query's own boundaries rotate/halve its blocks
/// ([`at_boundary`](Self::at_boundary)) — query boundaries never force
/// an epoch publish, so registering or dropping queries cannot move the
/// primary score sequence by a bit. Levels are chain-major (`m · L +
/// l`), keyed by row-major CMS bucket, exactly like the scorer's own
/// overlay.
#[derive(Debug, Clone)]
pub struct QueryState {
    pub name: String,
    pub spec: DecaySpec,
    /// Live block: published increments since the last rotation.
    pub cur: Vec<HashMap<u32, u32>>,
    /// Previous window block (empty while `spec.window == 0`).
    pub prev: Vec<HashMap<u32, u32>>,
    /// `SCORE <id> <name>` requests served against this query.
    pub scored: u64,
}

impl QueryState {
    pub fn new(name: String, spec: DecaySpec, num_levels: usize) -> QueryState {
        QueryState {
            name,
            spec,
            cur: vec![HashMap::new(); num_levels],
            prev: vec![HashMap::new(); num_levels],
            scored: 0,
        }
    }

    /// Add a published epoch increment (sorted `(bucket, count)` pairs
    /// per level) into the live block. Saturating adds commute, so the
    /// result is independent of how the increment was assembled.
    pub fn on_publish(&mut self, inc: &[Vec<(u32, u32)>]) {
        for (level, pairs) in self.cur.iter_mut().zip(inc) {
            for &(bucket, count) in pairs {
                let c = level.entry(bucket).or_insert(0);
                *c = c.saturating_add(count);
            }
        }
    }

    /// Apply this query's own due boundaries at the given submit count:
    /// rotation first, then halving (the same order the primary scorer
    /// uses when both coincide).
    pub fn at_boundary(&mut self, submitted: u64) {
        if self.spec.rotate_due(submitted) {
            self.prev = std::mem::replace(&mut self.cur, vec![HashMap::new(); self.prev.len()]);
        }
        if self.spec.halve_due(submitted) {
            for level in self.cur.iter_mut().chain(self.prev.iter_mut()) {
                decay_halve_overlay(level);
            }
        }
    }

    /// The query's full overlay for scoring: `cur + prev` merged with
    /// saturating adds (what `base + cur + prev` scoring reads).
    pub fn combined_levels(&self) -> Vec<HashMap<u32, u32>> {
        let mut combined = self.cur.clone();
        for (level, prev) in combined.iter_mut().zip(&self.prev) {
            for (&bucket, &count) in prev {
                let c = level.entry(bucket).or_insert(0);
                *c = c.saturating_add(count);
            }
        }
        combined
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_boundaries_are_pure_functions_of_the_clock() {
        let spec = DecaySpec::new(6, 4);
        assert!(spec.enabled());
        assert!(!spec.rotate_due(0), "submit 0 is never a boundary");
        assert!(!spec.halve_due(0));
        assert!(spec.rotate_due(4) && spec.rotate_due(8) && !spec.rotate_due(5));
        assert!(spec.halve_due(6) && spec.halve_due(12) && !spec.halve_due(4));
        let off = DecaySpec::default();
        assert!(!off.enabled());
        for t in 0..100 {
            assert!(!off.rotate_due(t) && !off.halve_due(t));
        }
    }

    #[test]
    fn query_names_validate_typed() {
        validate_query_name("decayed-1h").unwrap();
        validate_query_name("a.b_c-9").unwrap();
        for bad in ["", "with space", "tab\tname", "arrow->x", "emoji✓"] {
            assert!(
                matches!(validate_query_name(bad), Err(SparxError::InvalidParams(_))),
                "{bad:?} must be rejected"
            );
        }
        assert!(validate_query_name(&"x".repeat(MAX_QUERY_NAME)).is_ok());
        assert!(validate_query_name(&"x".repeat(MAX_QUERY_NAME + 1)).is_err());
    }

    #[test]
    fn query_state_rotates_halves_and_combines() {
        let mut q = QueryState::new("w".into(), DecaySpec::new(0, 2), 2);
        q.on_publish(&[vec![(1, 4)], vec![(7, 2)]]);
        assert_eq!(q.combined_levels()[0].get(&1), Some(&4));
        q.at_boundary(2); // rotate: cur → prev
        assert!(q.cur.iter().all(HashMap::is_empty));
        assert_eq!(q.prev[0].get(&1), Some(&4));
        q.on_publish(&[vec![(1, 1)], vec![]]);
        // combined = cur + prev
        assert_eq!(q.combined_levels()[0].get(&1), Some(&5));
        assert_eq!(q.combined_levels()[1].get(&7), Some(&2));
        q.at_boundary(4); // rotate again: the first window's mass is gone
        assert_eq!(q.combined_levels()[0].get(&1), Some(&1));
        assert_eq!(q.combined_levels()[1].get(&7), None);

        let mut h = QueryState::new("h".into(), DecaySpec::new(3, 0), 1);
        h.on_publish(&[vec![(0, 9)]]);
        h.at_boundary(3);
        assert_eq!(h.cur[0].get(&0), Some(&4), "floor halving");
        h.at_boundary(5); // not a boundary
        assert_eq!(h.cur[0].get(&0), Some(&4));
    }
}
