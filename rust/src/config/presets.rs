//! The paper's two system configurations (Table 5), scaled to one machine.
//!
//! | | #partitions | driver mem | exec mem | #execs | #exec cores | #threads |
//! |---|---|---|---|---|---|---|
//! | config-mod | 64 | 25GB | 4GB | 4 | 4 | 4 |
//! | config-gen | 128 | 45GB | 8GB | 64 | 8 | 128 |
//!
//! Scaling: partition counts are kept; worker counts are capped by local
//! cores but preserve the mod<gen ordering; memory budgets are scaled by
//! 1/64 (the same factor as the dataset scale-down) so that the MEM-ERR
//! behaviours reproduce at the same *relative* workload.

use crate::cluster::ClusterConfig;

const MB: usize = 1024 * 1024;

/// 'moderate' preset (paper config-mod, scaled).
pub fn config_mod() -> ClusterConfig {
    ClusterConfig {
        num_partitions: 64,
        num_workers: 4,
        num_threads: 4,
        worker_mem_bytes: 4 * 1024 * MB / 64, // 64MB: 4GB ÷ scale 64
        driver_mem_bytes: 25 * 1024 * MB / 64,
        network_bytes_per_sec: 1e9,
        network_secs_per_record: 25e-9,
        deadline_secs: Some(8.0 * 3600.0 / 64.0), // 8h SC budget, scaled
        seed: 0x5EED,
    }
}

/// 'generous' preset (paper config-gen, scaled).
pub fn config_gen() -> ClusterConfig {
    ClusterConfig {
        num_partitions: 128,
        num_workers: 8,
        num_threads: 8,
        worker_mem_bytes: 8 * 1024 * MB / 64,
        driver_mem_bytes: 45 * 1024 * MB / 64,
        network_bytes_per_sec: 2e9,
        network_secs_per_record: 25e-9,
        deadline_secs: Some(8.0 * 3600.0 / 64.0),
        seed: 0x5EED,
    }
}

/// Unconstrained local preset for tests and examples.
pub fn config_local() -> ClusterConfig {
    ClusterConfig {
        num_partitions: 8,
        num_workers: std::thread::available_parallelism().map_or(4, |p| p.get().min(8)),
        num_threads: 4,
        ..Default::default()
    }
}

pub fn by_name(name: &str) -> Option<ClusterConfig> {
    match name {
        "config-mod" | "mod" => Some(config_mod()),
        "config-gen" | "gen" => Some(config_gen()),
        "local" => Some(config_local()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_strictly_more_generous() {
        let m = config_mod();
        let g = config_gen();
        assert!(g.num_partitions > m.num_partitions);
        assert!(g.num_workers > m.num_workers);
        assert!(g.worker_mem_bytes > m.worker_mem_bytes);
        assert!(g.driver_mem_bytes > m.driver_mem_bytes);
        assert!(g.num_threads > m.num_threads);
    }

    #[test]
    fn lookup() {
        assert!(by_name("config-mod").is_some());
        assert!(by_name("gen").is_some());
        assert!(by_name("nope").is_none());
    }
}
