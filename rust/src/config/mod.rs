//! Configuration: cluster presets mirroring the paper's Table 5 and a
//! JSON config-file format for the CLI (in-tree JSON; the offline build
//! has no serde).

pub mod presets;

use crate::cluster::ClusterConfig;
use crate::util::Json;

/// File-format mirror of [`ClusterConfig`]. All fields optional; defaults
/// come from [`ClusterConfig::default`]. Memory fields are in MB (0 =
/// unlimited), bandwidth in MB/s.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfigFile {
    pub num_partitions: usize,
    pub num_workers: usize,
    pub num_threads: usize,
    pub worker_mem_mb: usize,
    pub driver_mem_mb: usize,
    pub network_mbps: f64,
    pub deadline_secs: Option<f64>,
    pub seed: u64,
}

impl Default for ClusterConfigFile {
    fn default() -> Self {
        let c = ClusterConfig::default();
        ClusterConfigFile {
            num_partitions: c.num_partitions,
            num_workers: c.num_workers,
            num_threads: c.num_threads,
            worker_mem_mb: 0,
            driver_mem_mb: 0,
            network_mbps: c.network_bytes_per_sec / 1e6,
            deadline_secs: c.deadline_secs,
            seed: c.seed,
        }
    }
}

impl ClusterConfigFile {
    pub fn into_config(self) -> ClusterConfig {
        ClusterConfig {
            num_partitions: self.num_partitions,
            num_workers: self.num_workers,
            num_threads: self.num_threads,
            worker_mem_bytes: if self.worker_mem_mb == 0 {
                usize::MAX
            } else {
                self.worker_mem_mb * 1024 * 1024
            },
            driver_mem_bytes: if self.driver_mem_mb == 0 {
                usize::MAX
            } else {
                self.driver_mem_mb * 1024 * 1024
            },
            network_bytes_per_sec: self.network_mbps * 1e6,
            network_secs_per_record: 25e-9,
            deadline_secs: self.deadline_secs,
            seed: self.seed,
        }
    }

    pub fn from_json(j: &Json) -> Self {
        let mut f = ClusterConfigFile::default();
        if let Some(v) = j.get("num_partitions").and_then(Json::as_usize) {
            f.num_partitions = v;
        }
        if let Some(v) = j.get("num_workers").and_then(Json::as_usize) {
            f.num_workers = v;
        }
        if let Some(v) = j.get("num_threads").and_then(Json::as_usize) {
            f.num_threads = v;
        }
        if let Some(v) = j.get("worker_mem_mb").and_then(Json::as_usize) {
            f.worker_mem_mb = v;
        }
        if let Some(v) = j.get("driver_mem_mb").and_then(Json::as_usize) {
            f.driver_mem_mb = v;
        }
        if let Some(v) = j.get("network_mbps").and_then(Json::as_f64) {
            f.network_mbps = v;
        }
        if let Some(v) = j.get("deadline_secs").and_then(Json::as_f64) {
            f.deadline_secs = Some(v);
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            f.seed = v as u64;
        }
        f
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("num_partitions", Json::Num(self.num_partitions as f64)),
            ("num_workers", Json::Num(self.num_workers as f64)),
            ("num_threads", Json::Num(self.num_threads as f64)),
            ("worker_mem_mb", Json::Num(self.worker_mem_mb as f64)),
            ("driver_mem_mb", Json::Num(self.driver_mem_mb as f64)),
            ("network_mbps", Json::Num(self.network_mbps)),
            (
                "deadline_secs",
                self.deadline_secs.map_or(Json::Null, Json::Num),
            ),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    pub fn load(path: &std::path::Path) -> crate::api::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| {
            crate::api::SparxError::InvalidParams(format!("{}: {e}", path.display()))
        })?;
        Ok(Self::from_json(&j))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_json() {
        let f = ClusterConfigFile { num_partitions: 32, ..Default::default() };
        let j = f.to_json();
        let g = ClusterConfigFile::from_json(&j);
        assert_eq!(f, g);
    }

    #[test]
    fn zero_mem_means_unlimited() {
        let c = ClusterConfigFile::default().into_config();
        assert_eq!(c.worker_mem_bytes, usize::MAX);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"num_workers": 2}"#).unwrap();
        let f = ClusterConfigFile::from_json(&j);
        assert_eq!(f.num_workers, 2);
        assert_eq!(f.num_partitions, ClusterConfigFile::default().num_partitions);
    }
}
