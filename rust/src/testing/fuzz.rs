//! Deterministic structure-aware fuzzing of the untrusted-input surface.
//!
//! The decoders this drives are exactly the bytes a deployment node
//! accepts from the outside world: model artifacts
//! ([`registry::load_bytes`](crate::api::registry::load_bytes)),
//! absorb-state checkpoints ([`AbsorbCheckpoint`]), the packed
//! varint/RLE counter codec ([`Decoder::u32_vec_packed`]), serve-input
//! lines ([`parse_update_line`]), the TCP wire grammar
//! ([`parse_request`] — data lines plus control verbs) and the detector
//! spec-string grammar ([`MethodSpec`] — `--method` arguments and
//! `members=` lists). The invariant,
//! enforced per input by [`exercise`]:
//!
//! > any byte string either decodes to a **typed error** or decodes to a
//! > value whose re-encoding is a **fixpoint** (encode∘decode∘encode =
//! > encode) — never a panic, hang, or unbounded allocation.
//!
//! Everything is deterministic: mutations come from the in-repo PCG
//! ([`Rng`]), so `fuzz(seed, n)` replays bit-identically and a CI
//! failure reproduces locally from the reported seed + iteration. Seeds
//! are *valid* encodings built in-process (a fitted model artifact, a
//! hand-built checkpoint, packed counter blocks, serve lines); mutators
//! are byte-level (flips, truncations, splices) plus grammar-aware
//! patches (length-field corruption, whole-file CRC fix-up so mutations
//! reach the block layer instead of dying at the outer checksum).
//!
//! The committed regression corpus lives in `rust/tests/corpus/`; the
//! replay test (`rust/tests/fuzz.rs`) runs every entry through
//! [`exercise`] and additionally bounds peak allocation with a counting
//! global allocator.

use crate::api::{registry, spec};
use crate::api::{FittedModel, MethodSpec, ModelArtifact};
use crate::cluster::ClusterConfig;
use crate::data::generators::GisetteGen;
use crate::data::stream::parse_update_line;
use crate::serve::wire::{parse_request, Request};
use crate::sparx::checkpoint::{AbsorbCheckpoint, QueryRecord};
use crate::sparx::{SparxModel, SparxParams};
use crate::util::codec::{crc32, Decoder, Encoder};
use crate::util::Rng;
use std::sync::OnceLock;

/// Inputs are capped so a mutated length field cannot make a single
/// iteration arbitrarily slow — the decoders' own caps bound work per
/// byte, so bounding bytes bounds time.
pub const MAX_INPUT: usize = 1 << 16;

/// Element cap handed to [`Decoder::u32_vec_packed`] by the codec
/// target, mirroring the CMS row caps the real decode paths pass.
pub const PACKED_CAP: usize = 1 << 16;

/// Counters from a fuzz run (all inputs completed without a panic).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FuzzReport {
    /// Inputs exercised.
    pub iterations: u64,
    /// Total target acceptances (an input can decode under several
    /// targets); the rest were typed rejections.
    pub accepted: u64,
}

/// Run every decode target against one input, asserting the round-trip
/// fixpoint invariant for accepted inputs. Returns how many targets
/// accepted. Panics (caught by [`fuzz`], fatal in a test) signal a real
/// defect: a decoder panic or a broken round trip.
pub fn exercise(input: &[u8]) -> u32 {
    let mut accepted = 0;
    accepted += u32::from(target_model_artifact(input));
    accepted += u32::from(target_checkpoint(input));
    accepted += u32::from(target_packed_codec(input));
    accepted += u32::from(target_update_lines(input));
    accepted += u32::from(target_wire_requests(input));
    accepted += u32::from(target_spec_strings(input));
    accepted
}

/// Deterministic mutational fuzzing: `iterations` inputs derived from
/// the seed corpus, every one run through [`exercise`] under
/// `catch_unwind`. `Err` carries the failing seed/iteration and an input
/// prefix for triage.
pub fn fuzz(seed: u64, iterations: u64) -> Result<FuzzReport, String> {
    let seeds = seed_corpus();
    let mut rng = Rng::new(seed ^ 0x5f5f_f322_7375);
    let mut report = FuzzReport::default();
    for iteration in 0..iterations {
        let base = seeds.get(rng.below(seeds.len() as u64) as usize);
        let mut input = base.cloned().unwrap_or_default();
        for _ in 0..=rng.below(3) {
            mutate(&mut input, &mut rng, seeds);
        }
        input.truncate(MAX_INPUT);
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| exercise(&input)));
        match run {
            Ok(accepted) => {
                report.iterations += 1;
                report.accepted += u64::from(accepted);
            }
            Err(payload) => {
                return Err(format!(
                    "fuzz(seed={seed}) panicked at iteration {iteration}: {} \
                     (input: {} bytes, prefix {})",
                    panic_text(payload.as_ref()),
                    input.len(),
                    hex_prefix(&input, 48),
                ));
            }
        }
    }
    Ok(report)
}

// ------------------------------------------------------------- targets

/// `registry::load_bytes` + encode∘decode fixpoint for accepted models.
fn target_model_artifact(input: &[u8]) -> bool {
    let Ok(model) = registry::load_bytes(input) else { return false };
    let first = model.to_artifact().expect("loaded model must re-encode").to_bytes();
    let again = registry::load_bytes(&first).expect("re-encoded model artifact must load");
    let second = again.to_artifact().expect("reloaded model must re-encode").to_bytes();
    assert_eq!(first, second, "model artifact encode∘decode must be a fixpoint");
    true
}

/// Checkpoint container + header/snapshot decode, with the same
/// fixpoint check. (Unknown artifact extensions are dropped on decode,
/// so bit-identity holds from the *first* re-encode onward.)
fn target_checkpoint(input: &[u8]) -> bool {
    let Ok(art) = ModelArtifact::from_bytes(input) else { return false };
    let Ok(ckpt) = AbsorbCheckpoint::from_artifact(&art) else { return false };
    let first = ckpt.to_artifact().to_bytes();
    let reread = ModelArtifact::from_bytes(&first).expect("re-encoded checkpoint must frame");
    let again = AbsorbCheckpoint::from_artifact(&reread).expect("re-encoded checkpoint decodes");
    assert_eq!(first, again.to_artifact().to_bytes(), "checkpoint must reach a fixpoint");
    true
}

/// Packed varint/RLE counter block: decode under the cap, then the
/// re-encode must round trip exactly and consume its whole encoding.
fn target_packed_codec(input: &[u8]) -> bool {
    let _ = Decoder::new(input).varint();
    let Ok(values) = Decoder::new(input).u32_vec_packed(PACKED_CAP) else { return false };
    let mut enc = Encoder::new();
    enc.put_u32_slice_packed(&values);
    let encoded = enc.into_bytes();
    let mut dec = Decoder::new(&encoded);
    let back = dec.u32_vec_packed(PACKED_CAP).expect("re-encoded packed block must decode");
    assert_eq!(values, back, "packed u32 block must round trip");
    assert_eq!(dec.remaining(), 0, "canonical packed encoding leaves no tail");
    true
}

/// Serve-input line grammar: parsed lines must render back to a line
/// that parses to the same triple.
fn target_update_lines(input: &[u8]) -> bool {
    let text = String::from_utf8_lossy(input);
    let mut any = false;
    for (i, line) in text.lines().take(64).enumerate() {
        if let Ok(Some(u)) = parse_update_line(i + 1, line) {
            // anything the parser accepted is representable by
            // construction (tokens are whitespace-free, δ finite, the
            // old value never contains an arrow) — a typed rejection
            // here is a real grammar asymmetry
            let rendered =
                u.to_line().expect("a parsed update line is always representable");
            let reparsed = parse_update_line(i + 1, &rendered)
                .expect("rendered update line must parse")
                .expect("rendered update line is never a comment");
            assert_eq!(reparsed, u, "update line must round trip through to_line");
            any = true;
        }
    }
    any
}

/// TCP wire grammar ([`parse_request`]): every line either fails typed
/// or parses to a request whose canonical rendering parses back to the
/// same request (covers the control verbs `parse_update_line` never
/// sees).
fn target_wire_requests(input: &[u8]) -> bool {
    let text = String::from_utf8_lossy(input);
    let mut any = false;
    for (i, line) in text.lines().take(64).enumerate() {
        let lineno = i + 1;
        if let Ok(Some(req)) = parse_request(lineno, line) {
            let rendered = match &req {
                Request::Update(u) => {
                    u.to_line().expect("a parsed update line is always representable")
                }
                Request::Score(id) => format!("SCORE {id}"),
                Request::ScoreNamed(id, name) => format!("SCORE {id} {name}"),
                Request::QueryAdd { name, half_life, window } => {
                    format!("QUERY ADD {name} {half_life} {window}")
                }
                Request::QueryDrop(name) => format!("QUERY DROP {name}"),
                Request::QueryList => "QUERY LIST".to_string(),
                Request::Stats => "STATS".to_string(),
                Request::Metrics => "METRICS".to_string(),
                Request::Checkpoint => "CHECKPOINT".to_string(),
                Request::Reshard(n) => format!("RESHARD {n}"),
                Request::Quit => "QUIT".to_string(),
                Request::Shutdown => "SHUTDOWN".to_string(),
            };
            let reparsed = parse_request(lineno, &rendered)
                .expect("rendered request must parse")
                .expect("rendered request is never a comment");
            assert_eq!(reparsed, req, "wire request must round trip");
            any = true;
        }
    }
    any
}

/// Detector spec-string grammar ([`MethodSpec`]): every line either
/// fails typed or parses to a spec whose canonical [`MethodSpec::print`]
/// re-parses to the same value — likewise for the `name(:key=val)*`
/// member form and comma-separated member lists — and
/// [`registry::create`] must stay panic-free on anything the grammar
/// admits (unknown names / keys / values are typed errors).
fn target_spec_strings(input: &[u8]) -> bool {
    let text = String::from_utf8_lossy(input);
    let mut any = false;
    for line in text.lines().take(64) {
        if let Ok(ms) = MethodSpec::parse(line) {
            let reparsed =
                MethodSpec::parse(&ms.print()).expect("canonical spec string must re-parse");
            assert_eq!(reparsed, ms, "spec string must round trip through print");
            // building from an accepted grammar line must fail typed or
            // succeed — never panic (fit never runs here)
            let _ = registry::create(line);
            any = true;
        }
        if let Ok(ms) = MethodSpec::parse_member(line) {
            let reparsed = MethodSpec::parse_member(&ms.print_member())
                .expect("canonical member spec must re-parse");
            assert_eq!(reparsed, ms, "member spec must round trip through print_member");
            any = true;
        }
        // comma-separated member lists share the grammar; rejections are
        // typed by construction
        let _ = spec::parse_members(line);
    }
    any
}

// ----------------------------------------------------- seeds + mutators

/// Valid encodings the mutators start from, built once in-process:
/// index 0 a fitted sparx model artifact, 1 a checkpoint artifact, 2–3
/// packed counter blocks, 4 serve lines, 5 a bare truncated header,
/// 6 wire control verbs, 7 detector spec strings.
pub fn seed_corpus() -> &'static [Vec<u8>] {
    static SEEDS: OnceLock<Vec<Vec<u8>>> = OnceLock::new();
    SEEDS.get_or_init(|| {
        vec![
            model_artifact_seed(),
            sample_checkpoint().to_artifact().to_bytes(),
            packed_block_seed(&[0, 0, 0, 7, 1, 0, 0, 0, 0, 9, u32::MAX, 0]),
            packed_block_seed(&[]),
            b"17 f3 0.5\n9 city ->paris\n# comment\n42 f0 -2e-3\n".to_vec(),
            b"SPRX\x03\x00".to_vec(),
            b"SCORE 17\nSCORE 17 decayed.1k\nQUERY ADD decayed.1k 1024 256\nQUERY LIST\n\
              QUERY DROP decayed.1k\nSTATS\nRESHARD 4\nCHECKPOINT\nMETRICS\nQUIT\nSHUTDOWN\n"
                .to_vec(),
            b"ensemble?members=sparx:depth=6:seed=3,xstream&distill=true&schedule=round-robin\n\
              sparx?k=12&chains=8&depth=10&rate=0.5&seed=7\ndbscout?eps=0.25&min-pts=4\n\
              xstream\nspif?trees=20&depth=8\nensemble?members=sparx,xstream&share=false\n"
                .to_vec(),
        ]
    })
}

/// A real (tiny) fitted model, so artifact mutations explore the sparx
/// payload decoder, not just the container framing.
fn model_artifact_seed() -> Vec<u8> {
    let ctx = ClusterConfig { num_partitions: 2, ..Default::default() }.build();
    let data = GisetteGen { n: 120, d: 8, ..Default::default() }
        .generate(&ctx)
        .expect("seed dataset generates");
    let params = SparxParams { k: 4, num_chains: 2, depth: 3, ..Default::default() };
    let model = SparxModel::fit(&ctx, &data.dataset, &params).expect("seed model fits");
    model.to_artifact().expect("seed model encodes").to_bytes()
}

/// A hand-built v5 checkpoint exercising seq-tagged sketches, both
/// overlays, the decay/window blocks, a named query and the varint-gap
/// level encoding.
pub fn sample_checkpoint() -> AbsorbCheckpoint {
    let (num_chains, depth, k) = (2usize, 2usize, 3usize);
    AbsorbCheckpoint {
        model_fingerprint: 0xDEAD_BEEF,
        schema_fingerprint: 0x5A5A_0001,
        shards: 2,
        cache_total: 4,
        submitted: 17,
        absorb: true,
        half_life: 8,
        window: 6,
        k,
        depth,
        num_chains,
        cms_rows: 4,
        cms_cols: 128,
        processed: 48,
        evicted: 4,
        absorbed: 38,
        entries: vec![
            (0, 3, vec![0.5f32; k]),
            (2, 7, vec![-1.25f32; k]),
            (8, 12, vec![0.5f32; k]),
            (10, 16, vec![f32::MIN_POSITIVE; k]),
        ],
        visible: vec![
            vec![(0, 1), (5, 2)],
            vec![],
            vec![(63, 9)],
            vec![(2, 2), (3, 1), (100, 7)],
        ],
        pending: vec![vec![(1, 1)], vec![], vec![], vec![(7, 3)]],
        prev_visible: vec![vec![(4, 2)], vec![], vec![(0, 1), (64, 5)], vec![]],
        queries: vec![QueryRecord {
            name: "decayed.1k".into(),
            half_life: 4,
            window: 2,
            scored: 5,
            cur: vec![vec![(1, 2)], vec![], vec![], vec![(9, 1)]],
            prev: vec![vec![], vec![(3, 4)], vec![], vec![]],
        }],
    }
}

fn packed_block_seed(values: &[u32]) -> Vec<u8> {
    let mut enc = Encoder::new();
    enc.put_u32_slice_packed(values);
    enc.into_bytes()
}

/// One random mutation. Mostly byte-level; the last three arms are
/// grammar-aware (length-field patches, hostile-token injection for the
/// line grammars, and whole-file CRC repair so a mutated artifact
/// passes the outer checksum and reaches the block decoders).
fn mutate(input: &mut Vec<u8>, rng: &mut Rng, seeds: &[Vec<u8>]) {
    match rng.below(9) {
        0 => {
            // bit flip
            if let Some(pos) = random_pos(input, rng) {
                input[pos] ^= 1 << rng.below(8);
            }
        }
        1 => {
            // byte overwrite
            if let Some(pos) = random_pos(input, rng) {
                input[pos] = rng.next_u32() as u8;
            }
        }
        2 => {
            // truncate
            let keep = rng.below(input.len() as u64 + 1) as usize;
            input.truncate(keep);
        }
        3 => {
            // insert a byte
            let pos = rng.below(input.len() as u64 + 1) as usize;
            input.insert(pos, rng.next_u32() as u8);
        }
        4 => {
            // splice a window from another seed over this input
            let donor = &seeds[rng.below(seeds.len() as u64) as usize];
            if let (Some(dst), Some(src)) = (random_pos(input, rng), random_pos(donor, rng)) {
                let n = (rng.below(64) as usize + 1).min(donor.len() - src).min(input.len() - dst);
                input[dst..dst + n].copy_from_slice(&donor[src..src + n]);
            }
        }
        5 => {
            // patch a little-endian u32 (length/count fields live here)
            if input.len() >= 4 {
                let pos = rng.below(input.len() as u64 - 3) as usize;
                let v = match rng.below(4) {
                    0 => 0u32,
                    1 => rng.below(16) as u32,
                    2 => u32::MAX,
                    _ => rng.next_u32(),
                };
                input[pos..pos + 4].copy_from_slice(&v.to_le_bytes());
            }
        }
        6 => {
            // zero a span
            if let Some(pos) = random_pos(input, rng) {
                let n = (rng.below(16) as usize + 1).min(input.len() - pos);
                for b in &mut input[pos..pos + n] {
                    *b = 0;
                }
            }
        }
        7 => {
            // hostile-name injection aimed at the line grammars: arrows
            // that move the categorical split, whitespace that
            // re-tokenizes, non-finite δ tokens, over-long and
            // non-ASCII query names, spec-string punctuation that moves
            // the name/params and key/value splits — the
            // render/parse asymmetry class
            const HOSTILE: &[&[u8]] = &[
                b"->",
                b"a->b->c",
                b" ",
                b"\t",
                b"NaN",
                b"inf",
                b"9 loc ->\n",
                b"9 loc a->b->c\n",
                b"QUERY ADD a->b 1 1\n",
                b"QUERY ADD \xe2\x9c\x93 1 1\n",
                b"SCORE 1 xxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxx\
                  xxxxxxxxxxxxxxxxxxxxxxxxx\n",
                b"?",
                b"&",
                b"=",
                b"members=",
            ];
            let frag = HOSTILE[rng.below(HOSTILE.len() as u64) as usize];
            let pos = rng.below(input.len() as u64 + 1) as usize;
            input.splice(pos..pos, frag.iter().copied());
        }
        _ => {
            // repair the whole-file CRC so the mutation survives the
            // outer gate and exercises the inner block decoders
            if input.len() > 4 {
                let body = input.len() - 4;
                let sum = crc32(&input[..body]).to_le_bytes();
                input[body..].copy_from_slice(&sum);
            }
        }
    }
}

fn random_pos(bytes: &[u8], rng: &mut Rng) -> Option<usize> {
    if bytes.is_empty() {
        None
    } else {
        Some(rng.below(bytes.len() as u64) as usize)
    }
}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn hex_prefix(bytes: &[u8], n: usize) -> String {
    let mut s = String::with_capacity(2 * n.min(bytes.len()) + 1);
    for b in bytes.iter().take(n) {
        s.push_str(&format!("{b:02x}"));
    }
    if bytes.len() > n {
        s.push('…');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_are_valid() {
        // every seed must be accepted by at least one target (except the
        // deliberately-truncated header, which must be rejected typed)
        let seeds = seed_corpus();
        assert!(exercise(&seeds[0]) >= 1, "model seed accepted");
        assert!(exercise(&seeds[1]) >= 1, "checkpoint seed accepted");
        assert!(exercise(&seeds[2]) >= 1, "packed seed accepted");
        assert!(exercise(&seeds[4]) >= 1, "line seed accepted");
        assert_eq!(exercise(&seeds[5]), 0, "truncated header rejected everywhere");
        assert!(exercise(&seeds[6]) >= 1, "wire verb seed accepted");
        assert!(exercise(&seeds[7]) >= 1, "spec string seed accepted");
    }

    #[test]
    fn fuzz_is_deterministic() {
        let a = fuzz(7, 40).expect("no panics");
        let b = fuzz(7, 40).expect("no panics");
        assert_eq!(a, b);
        assert_eq!(a.iterations, 40);
    }

    #[test]
    fn fuzz_smoke() {
        let report = fuzz(1, 150).expect("decoders must never panic");
        assert_eq!(report.iterations, 150);
    }
}
