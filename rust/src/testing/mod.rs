//! Test-support subsystem: deterministic, structure-aware fuzzing of the
//! untrusted-input decoders ([`fuzz`]). Ships in the library (not under
//! `#[cfg(test)]`) so the corpus replay test, the CI fuzz-smoke job and
//! ad-hoc triage all drive the exact same code.

pub mod fuzz;
