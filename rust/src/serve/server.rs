//! The listener and the shared serving engine.
//!
//! One [`Engine`] (a mutex around the session's [`ShardedStreamScorer`]
//! plus the checkpoint configuration) is shared by every connection;
//! holding its lock is the only way to assign a submit sequence number,
//! so the global stream order — and with it eviction and absorb-epoch
//! determinism — is exactly as well-defined under N concurrent clients
//! as under one stdin reader. Connections hold the lock only for
//! constant-time work (a `try_submit`, a flush, a counter probe); the
//! heavy lifting happens on the shard workers behind their bounded
//! queues.
//!
//! The [`Server`] owns the accept loop: one reader thread (plus one
//! writer thread, see [`super::conn`]) per connection, a shared
//! shutdown latch, and a registry of open sockets so a graceful
//! `SHUTDOWN` can unblock readers stuck in `read()` by closing their
//! sockets. [`Server::run`] returns the scorer once the last
//! connection drains, so the caller finishes it — final report, score
//! log, checkpoint — exactly like the stdin path.

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::api::{Result, SparxError};
use crate::data::UpdateTriple;
use crate::sparx::sharded::{QueryInfo, ReplySink, ShardedStats, ShardedStreamScorer, WouldBlock};
use crate::sparx::MemberInfo;

use super::conn::handle_conn;

/// Mutex lock that survives a poisoned peer: a connection thread that
/// panicked mid-probe must not wedge every other client, and the scorer
/// state itself is only ever mutated through `&mut` methods that keep
/// their invariants on early return.
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// The serving engine every connection talks to: the sharded scorer
/// plus what the `CHECKPOINT` verb needs (target path, provenance).
pub struct Engine {
    scorer: Option<ShardedStreamScorer>,
    model_path: String,
    checkpoint_out: Option<String>,
}

impl Engine {
    /// Wrap a running scorer. `model_path` travels into checkpoint
    /// manifests; `checkpoint_out` arms the `CHECKPOINT` verb (without
    /// it the verb answers a typed error).
    pub fn new(
        scorer: ShardedStreamScorer,
        model_path: impl Into<String>,
        checkpoint_out: Option<String>,
    ) -> Engine {
        Engine { scorer: Some(scorer), model_path: model_path.into(), checkpoint_out }
    }

    fn scorer_mut(&mut self) -> Result<&mut ShardedStreamScorer> {
        self.scorer
            .as_mut()
            .ok_or_else(|| SparxError::Io("the serving engine is shutting down".into()))
    }

    /// Non-blocking submit (see [`ShardedStreamScorer::try_submit`]):
    /// the inner `Err(WouldBlock)` is the shard-queue-full signal the
    /// connection renders as `BUSY` — the update was not accepted and
    /// no sequence number was consumed.
    pub fn try_submit(
        &mut self,
        u: UpdateTriple,
        reply: ReplySink,
    ) -> Result<std::result::Result<(), WouldBlock>> {
        Ok(self.scorer_mut()?.try_submit(u, Some(reply)))
    }

    /// Push buffered batches to the shards (see
    /// [`ShardedStreamScorer::flush`]) — connections call this once per
    /// read chunk so replies materialize promptly on idle streams.
    pub fn flush(&mut self) -> Result<()> {
        self.scorer_mut()?.flush();
        Ok(())
    }

    /// Read-only score probe (the `SCORE` verb).
    pub fn query(&mut self, id: u64, reply: ReplySink) -> Result<()> {
        self.scorer_mut()?.query_score(id, reply);
        Ok(())
    }

    /// Score probe against a named query (`SCORE <id> <name>`).
    pub fn query_named(&mut self, id: u64, name: &str, reply: ReplySink) -> Result<()> {
        self.scorer_mut()?.score_named(id, name, reply)
    }

    /// Register a named `(half-life, window)` query (`QUERY ADD`).
    pub fn query_add(&mut self, name: &str, half_life: u64, window: u64) -> Result<()> {
        self.scorer_mut()?.query_add(name, half_life, window)
    }

    /// Drop a named query and its blocks (`QUERY DROP`).
    pub fn query_drop(&mut self, name: &str) -> Result<()> {
        self.scorer_mut()?.query_drop(name)
    }

    /// Snapshot of the registered queries (`QUERY LIST`).
    pub fn query_list(&mut self) -> Result<Vec<QueryInfo>> {
        Ok(self.scorer_mut()?.query_list())
    }

    /// Live counters (the `STATS`/`METRICS` verbs).
    pub fn stats(&mut self) -> Result<ShardedStats> {
        self.scorer_mut()?.stats()
    }

    /// Live re-shard (the `RESHARD` verb). Returns the new shard count.
    pub fn reshard(&mut self, shards: usize) -> Result<usize> {
        let scorer = self.scorer_mut()?;
        scorer.reshard(shards)?;
        Ok(scorer.shards())
    }

    /// Cut a checkpoint to the configured `--checkpoint-out` path (the
    /// `CHECKPOINT` verb). Returns the submit watermark it covers.
    pub fn checkpoint(&mut self) -> Result<u64> {
        let Some(out) = self.checkpoint_out.clone() else {
            return Err(SparxError::InvalidParams(
                "CHECKPOINT: the server was started without --checkpoint-out".into(),
            ));
        };
        let model_path = self.model_path.clone();
        let scorer = self.scorer_mut()?;
        let ckpt = scorer.checkpoint()?;
        let manifest = ckpt.manifest_for(&model_path);
        ckpt.save(&out, manifest)?;
        Ok(ckpt.submitted)
    }

    /// Take the scorer out for finalization (report, score log, final
    /// checkpoint). Subsequent engine calls fail typed.
    pub fn take_scorer(&mut self) -> Option<ShardedStreamScorer> {
        self.scorer.take()
    }
}

/// Render the registered queries as one JSON array (shared by the
/// `STATS` and `QUERY LIST` renderings). Query names are
/// `[A-Za-z0-9._-]` by construction, so no JSON escaping is needed.
pub fn queries_json(queries: &[QueryInfo]) -> String {
    let items: Vec<String> = queries
        .iter()
        .map(|q| {
            format!(
                "{{\"name\":\"{}\",\"half_life\":{},\"window\":{},\"scored\":{}}}",
                q.name, q.half_life, q.window, q.scored
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

/// Quote `s` as a JSON string. Member spec text comes from the detector
/// spec grammar, whose values are user-written — escape defensively
/// rather than trusting the character set.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Render the served model's per-member provenance (ensemble models
/// only; empty for single-detector models) as one JSON array.
fn members_json(members: &[MemberInfo]) -> String {
    let items: Vec<String> = members
        .iter()
        .map(|m| {
            format!(
                "{{\"spec\":{},\"kind\":{},\"fit_micros\":{},\"score_micros\":{},\
                 \"worker\":{},\"distilled_from\":{},\"serving\":{}}}",
                json_str(&m.spec),
                json_str(&m.kind),
                m.fit_micros,
                m.score_micros,
                m.worker,
                m.distilled_from.as_deref().map_or_else(|| "null".into(), json_str),
                m.serving,
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

/// Render live stats as the single-line JSON the `STATS` verb returns:
/// the merged [`ShardedStats`] counters plus the resident-byte
/// accounting, the registered queries and the ensemble member
/// provenance. Key order is fixed — the line is meant to be parsed; new
/// keys are only ever appended.
pub fn stats_json(stats: &ShardedStats) -> String {
    format!(
        "{{\"shards\":{},\"submitted\":{},\"processed\":{},\"admitted\":{},\
         \"evictions\":{},\"absorbed\":{},\"resident_ids\":{},\
         \"resident_ensemble_bytes\":{},\"resident_sketch_bytes\":{},\"resident_bytes\":{},\
         \"queries\":{},\"members\":{}}}",
        stats.shards.len(),
        stats.submitted,
        stats.processed(),
        stats.admitted(),
        stats.evictions(),
        stats.absorbed(),
        stats.resident_ids,
        stats.resident_ensemble_bytes,
        stats.resident_sketch_bytes,
        stats.resident_bytes(),
        queries_json(&stats.queries),
        members_json(&stats.members),
    )
}

/// Render live stats in the text metrics exposition format (the
/// `METRICS` verb): `# TYPE` headers, one sample per line, terminated
/// by a `# EOF` marker so a line-oriented client knows when to stop.
pub fn metrics_text(stats: &ShardedStats) -> String {
    let mut out = String::new();
    let mut counter = |name: &str, help: &str, v: u64| {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
        ));
    };
    counter("sparx_submitted_total", "updates submitted to the serving plane", stats.submitted);
    counter("sparx_processed_total", "updates processed by shard workers", stats.processed());
    counter("sparx_admitted_total", "sketch cache admissions", stats.admitted());
    counter("sparx_evictions_total", "sketch cache evictions", stats.evictions());
    counter("sparx_absorbed_total", "points absorbed into the density overlays", stats.absorbed());
    let mut gauge = |name: &str, help: &str, v: u64| {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"
        ));
    };
    gauge("sparx_shards", "live shard worker threads", stats.shards.len() as u64);
    gauge("sparx_resident_ids", "sketches resident in the cache", stats.resident_ids as u64);
    gauge(
        "sparx_resident_bytes",
        "resident bytes (shared ensemble + sketches)",
        stats.resident_bytes() as u64,
    );
    gauge("sparx_queries", "registered named queries", stats.queries.len() as u64);
    gauge(
        "sparx_ensemble_members",
        "members behind the served model (0 for single-detector models)",
        stats.members.len() as u64,
    );
    if !stats.queries.is_empty() {
        out.push_str(
            "# HELP sparx_query_scored_total named-query score probes served\n\
             # TYPE sparx_query_scored_total counter\n",
        );
        for q in &stats.queries {
            out.push_str(&format!(
                "sparx_query_scored_total{{query=\"{}\"}} {}\n",
                q.name, q.scored
            ));
        }
    }
    if !stats.members.is_empty() {
        out.push_str(
            "# HELP sparx_member_fit_micros measured member fit cost on the training run\n\
             # TYPE sparx_member_fit_micros gauge\n",
        );
        for m in &stats.members {
            out.push_str(&format!(
                "sparx_member_fit_micros{{member=\"{}\",kind=\"{}\"}} {}\n",
                m.spec, m.kind, m.fit_micros
            ));
        }
        out.push_str(
            "# HELP sparx_member_score_micros measured member calibration-score cost\n\
             # TYPE sparx_member_score_micros gauge\n",
        );
        for m in &stats.members {
            out.push_str(&format!(
                "sparx_member_score_micros{{member=\"{}\",kind=\"{}\"}} {}\n",
                m.spec, m.kind, m.score_micros
            ));
        }
        out.push_str(
            "# HELP sparx_member_serving 1 on the member backing the serve path\n\
             # TYPE sparx_member_serving gauge\n",
        );
        for m in &stats.members {
            out.push_str(&format!(
                "sparx_member_serving{{member=\"{}\"}} {}\n",
                m.spec,
                u8::from(m.serving)
            ));
        }
    }
    out.push_str("# EOF\n");
    out
}

/// State shared by the accept loop and every connection thread.
pub(crate) struct Shared {
    pub(crate) engine: Mutex<Engine>,
    pub(crate) shutdown: AtomicBool,
    /// The bound address — a `SHUTDOWN` handler connects to it to wake
    /// the accept loop out of its blocking `accept()`.
    pub(crate) local: SocketAddr,
    /// Clones of every accepted socket, so shutdown can unblock readers
    /// stuck in `read()` by closing them.
    conns: Mutex<Vec<TcpStream>>,
}

impl Shared {
    /// Trip the shutdown latch and wake the accept loop.
    pub(crate) fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // failing to connect means the listener is already gone — fine
        let _ = TcpStream::connect(self.local);
    }
}

/// The TCP ingress: `sparx serve --listen ADDR`.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:7341`, or port `0` to let the OS
    /// pick — read it back via [`local_addr`](Self::local_addr)).
    pub fn bind(addr: &str, engine: Engine) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| SparxError::Io(format!("cannot listen on {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| SparxError::Io(format!("cannot resolve the bound address: {e}")))?;
        Ok(Server {
            listener,
            shared: Arc::new(Shared {
                engine: Mutex::new(engine),
                shutdown: AtomicBool::new(false),
                local,
                conns: Mutex::new(Vec::new()),
            }),
        })
    }

    /// The actually-bound address (resolves port `0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.local
    }

    /// Accept and serve connections until a client issues `SHUTDOWN`,
    /// then drain every open connection and hand the scorer back for
    /// finalization. Accept errors on individual connections are
    /// transient (logged to stderr); only a dead listener is fatal.
    pub fn run(self) -> Result<ShardedStreamScorer> {
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        for stream in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("sparx: serve: accept failed ({e}); continuing");
                    continue;
                }
            };
            if let Ok(clone) = stream.try_clone() {
                lock(&self.shared.conns).push(clone);
            }
            let shared = self.shared.clone();
            handles.push(std::thread::spawn(move || handle_conn(stream, shared)));
            // reap finished connection threads as we go
            handles = handles
                .into_iter()
                .filter_map(|h| {
                    if h.is_finished() {
                        let _ = h.join();
                        None
                    } else {
                        Some(h)
                    }
                })
                .collect();
        }
        // unblock any reader still parked in read(): close every socket
        for s in lock(&self.shared.conns).drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        for h in handles {
            let _ = h.join();
        }
        drop(self.listener);
        lock(&self.shared.engine)
            .take_scorer()
            .ok_or_else(|| SparxError::Io("the serving engine was already taken".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparx::sharded::ShardCounters;

    fn sample_stats() -> ShardedStats {
        ShardedStats {
            shards: vec![
                ShardCounters {
                    processed: 30,
                    admitted: 20,
                    evictions: 4,
                    cached_ids: 16,
                    absorbed: 30,
                },
                ShardCounters {
                    processed: 20,
                    admitted: 14,
                    evictions: 2,
                    cached_ids: 12,
                    absorbed: 20,
                },
            ],
            submitted: 50,
            resident_ids: 28,
            resident_ensemble_bytes: 1000,
            resident_sketch_bytes: 28 * 8 * 4,
            queries: vec![
                QueryInfo { name: "decayed.1k".into(), half_life: 1024, window: 0, scored: 7 },
                QueryInfo { name: "w-256".into(), half_life: 0, window: 256, scored: 0 },
            ],
            members: vec![
                MemberInfo {
                    spec: "xstream:depth=12".into(),
                    kind: "xstream".into(),
                    fit_micros: 900,
                    score_micros: 40,
                    worker: 1,
                    distilled_from: None,
                    serving: false,
                },
                MemberInfo {
                    spec: "sparx:distilled".into(),
                    kind: "sparx".into(),
                    fit_micros: 120,
                    score_micros: 9,
                    worker: 0,
                    distilled_from: Some("xstream:depth=12".into()),
                    serving: true,
                },
            ],
        }
    }

    #[test]
    fn stats_json_is_one_parseable_line() {
        let line = stats_json(&sample_stats());
        assert!(!line.contains('\n'), "STATS must be a single line");
        let v = crate::util::json::Json::parse(&line).expect("STATS line must parse as JSON");
        assert_eq!(v.get("shards").and_then(|j| j.as_f64()), Some(2.0));
        assert_eq!(v.get("submitted").and_then(|j| j.as_f64()), Some(50.0));
        assert_eq!(v.get("processed").and_then(|j| j.as_f64()), Some(50.0));
        assert_eq!(v.get("evictions").and_then(|j| j.as_f64()), Some(6.0));
        assert_eq!(
            v.get("resident_bytes").and_then(|j| j.as_f64()),
            Some((1000 + 28 * 8 * 4) as f64)
        );
        // the queries array rides along, in registration order
        assert!(line.contains(
            "\"queries\":[{\"name\":\"decayed.1k\",\"half_life\":1024,\"window\":0,\"scored\":7}"
        ));
        assert!(line.contains("{\"name\":\"w-256\",\"half_life\":0,\"window\":256,\"scored\":0}"));
        // member provenance is appended last, with distillation lineage
        assert!(line.contains(
            "\"members\":[{\"spec\":\"xstream:depth=12\",\"kind\":\"xstream\",\
             \"fit_micros\":900,\"score_micros\":40,\"worker\":1,\
             \"distilled_from\":null,\"serving\":false}"
        ));
        assert!(line.contains(
            "{\"spec\":\"sparx:distilled\",\"kind\":\"sparx\",\"fit_micros\":120,\
             \"score_micros\":9,\"worker\":0,\"distilled_from\":\"xstream:depth=12\",\
             \"serving\":true}"
        ));
    }

    #[test]
    fn member_json_escapes_hostile_spec_text() {
        assert_eq!(json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_str("tab\there"), "\"tab\\u0009here\"");
    }

    #[test]
    fn queries_json_renders_empty_and_populated() {
        assert_eq!(queries_json(&[]), "[]");
        let one = [QueryInfo { name: "q".into(), half_life: 4, window: 8, scored: 2 }];
        assert_eq!(
            queries_json(&one),
            "[{\"name\":\"q\",\"half_life\":4,\"window\":8,\"scored\":2}]"
        );
    }

    #[test]
    fn metrics_text_is_terminated_and_typed() {
        let text = metrics_text(&sample_stats());
        assert!(text.ends_with("# EOF\n"), "metrics dump must be EOF-terminated");
        for name in [
            "sparx_submitted_total",
            "sparx_processed_total",
            "sparx_evictions_total",
            "sparx_resident_bytes",
            "sparx_shards",
        ] {
            assert!(text.contains(&format!("# TYPE {name} ")), "missing {name} type line");
        }
        assert!(text.contains("sparx_submitted_total 50\n"));
        assert!(text.contains("sparx_shards 2\n"));
        // per-query labeled counters
        assert!(text.contains("sparx_queries 2\n"));
        assert!(text.contains("sparx_query_scored_total{query=\"decayed.1k\"} 7\n"));
        assert!(text.contains("sparx_query_scored_total{query=\"w-256\"} 0\n"));
        // per-member labeled gauges, with the serving marker
        assert!(text.contains("sparx_ensemble_members 2\n"));
        assert!(text.contains(
            "sparx_member_fit_micros{member=\"xstream:depth=12\",kind=\"xstream\"} 900\n"
        ));
        assert!(text.contains(
            "sparx_member_score_micros{member=\"sparx:distilled\",kind=\"sparx\"} 9\n"
        ));
        assert!(text.contains("sparx_member_serving{member=\"sparx:distilled\"} 1\n"));
        assert!(text.contains("sparx_member_serving{member=\"xstream:depth=12\"} 0\n"));
    }

    #[test]
    fn engine_without_checkpoint_path_rejects_the_verb_typed() {
        // no scorer needed to hit the configuration check — build the
        // engine shell directly
        let mut engine =
            Engine { scorer: None, model_path: "m.sparx".into(), checkpoint_out: None };
        match engine.checkpoint() {
            Err(SparxError::InvalidParams(msg)) => {
                assert!(msg.contains("--checkpoint-out"), "got {msg:?}");
            }
            other => panic!("expected a typed rejection, got {other:?}"),
        }
        // with a path but no scorer: the shutting-down error surfaces
        engine.checkpoint_out = Some("c.sparx".into());
        assert!(matches!(engine.checkpoint(), Err(SparxError::Io(_))));
    }
}
