//! The TCP line grammar: what one `\n`-terminated request line may say.
//!
//! Data lines are **exactly** the `sparx serve --updates` grammar
//! ([`parse_update_line`]): `ID FEATURE δ` or `ID FEATURE old->new`,
//! blank lines and `#` comments skipped — a file that drives the stdin
//! path drives a socket unchanged, and scores come out bit-identical.
//! Control verbs are distinguishable on the first token alone: an
//! update line starts with a numeric ID, a verb with an upper-case
//! keyword, so neither grammar can shadow the other.
//!
//! ```text
//! SCORE <id>     → SCORE <id> <score-bits-hex> | UNKNOWN <id>
//! STATS          → STATS {…one JSON line…}
//! METRICS        → text-format metrics dump, terminated by `# EOF`
//! CHECKPOINT     → OK checkpoint <submitted>
//! RESHARD <n>    → OK reshard <n>
//! QUIT           → OK bye, server closes this connection
//! SHUTDOWN       → OK shutdown, server stops accepting and exits
//! <update line>  → OK <id> <score-bits-hex>   (or BUSY <id>)
//! ```
//!
//! Malformed lines answer `ERR <reason>` and the connection stays open;
//! lines longer than [`MAX_LINE_BYTES`] are rejected typed the same way
//! (the overflow is discarded up to the next newline). Responses to one
//! ID always arrive in submit order; responses across IDs may
//! interleave (different shards drain independently).

use crate::api::{Result, SparxError};
use crate::data::{parse_update_line, UpdateTriple};

/// Hard cap on one request line (bytes, excluding the `\n`). A line that
/// exceeds it is rejected with a typed `ERR` — never silently truncated,
/// never buffered unboundedly.
pub const MAX_LINE_BYTES: usize = 8192;

/// One parsed request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// A ⟨ID, F, δ⟩ data line — scored by the owning shard.
    Update(UpdateTriple),
    /// Read-only score probe for a resident ID.
    Score(u64),
    /// One-line JSON counter dump.
    Stats,
    /// Text-format metrics dump (`# EOF` terminated).
    Metrics,
    /// Cut a checkpoint to the server's configured `--checkpoint-out`.
    Checkpoint,
    /// Live re-shard to `n` worker threads, between batches, lossless.
    Reshard(usize),
    /// Close this connection (after draining its pending replies).
    Quit,
    /// Stop the whole server gracefully.
    Shutdown,
}

/// Parse one request line. Blank lines and `#` comments yield
/// `Ok(None)`; anything malformed is a typed `SparxError::InvalidParams`
/// naming the line number (rendered as an `ERR` response on the wire).
pub fn parse_request(lineno: usize, line: &str) -> Result<Option<Request>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let bad = |what: String| {
        SparxError::InvalidParams(format!("request line {lineno}: {what}"))
    };
    let mut tok = line.split_whitespace();
    let Some(verb) = tok.next() else {
        return Ok(None);
    };
    let arg = tok.next();
    let extra = tok.next();
    match verb {
        "SCORE" => {
            if extra.is_some() {
                return Err(bad("SCORE takes exactly one argument (the ID)".into()));
            }
            let Some(id_tok) = arg else {
                return Err(bad("SCORE needs an ID argument".into()));
            };
            let id: u64 = id_tok
                .parse()
                .map_err(|_| bad(format!("SCORE: bad ID {id_tok:?}")))?;
            Ok(Some(Request::Score(id)))
        }
        "RESHARD" => {
            if extra.is_some() {
                return Err(bad("RESHARD takes exactly one argument (the shard count)".into()));
            }
            let Some(n_tok) = arg else {
                return Err(bad("RESHARD needs a shard count argument".into()));
            };
            let n: usize = n_tok
                .parse()
                .map_err(|_| bad(format!("RESHARD: bad shard count {n_tok:?}")))?;
            if n == 0 {
                return Err(bad("RESHARD: shard count must be ≥ 1".into()));
            }
            Ok(Some(Request::Reshard(n)))
        }
        "STATS" | "METRICS" | "CHECKPOINT" | "QUIT" | "SHUTDOWN" => {
            if arg.is_some() {
                return Err(bad(format!("{verb} takes no arguments")));
            }
            Ok(Some(match verb {
                "STATS" => Request::Stats,
                "METRICS" => Request::Metrics,
                "CHECKPOINT" => Request::Checkpoint,
                "QUIT" => Request::Quit,
                _ => Request::Shutdown,
            }))
        }
        _ => {
            // not a verb: the whole line must be an update triple (its
            // first token is a numeric ID, so the grammars are disjoint;
            // parse_update_line produces the typed error otherwise)
            Ok(parse_update_line(lineno, line)?.map(Request::Update))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verbs_parse() {
        assert_eq!(parse_request(1, "SCORE 42").unwrap(), Some(Request::Score(42)));
        assert_eq!(parse_request(1, "STATS").unwrap(), Some(Request::Stats));
        assert_eq!(parse_request(1, "METRICS").unwrap(), Some(Request::Metrics));
        assert_eq!(parse_request(1, "CHECKPOINT").unwrap(), Some(Request::Checkpoint));
        assert_eq!(parse_request(1, "RESHARD 4").unwrap(), Some(Request::Reshard(4)));
        assert_eq!(parse_request(1, "QUIT").unwrap(), Some(Request::Quit));
        assert_eq!(parse_request(1, "SHUTDOWN").unwrap(), Some(Request::Shutdown));
        assert_eq!(parse_request(1, "  QUIT  ").unwrap(), Some(Request::Quit));
    }

    #[test]
    fn update_lines_delegate_to_the_stream_grammar() {
        match parse_request(3, "42 bytes_sent 1.5").unwrap() {
            Some(Request::Update(UpdateTriple::Num { id, feature, delta })) => {
                assert_eq!((id, feature.as_str(), delta), (42, "bytes_sent", 1.5));
            }
            other => panic!("expected an update, got {other:?}"),
        }
        match parse_request(4, "7 loc NYC->Austin").unwrap() {
            Some(Request::Update(UpdateTriple::Cat { id, .. })) => assert_eq!(id, 7),
            other => panic!("expected a categorical update, got {other:?}"),
        }
    }

    #[test]
    fn comments_and_blanks_are_skipped() {
        assert_eq!(parse_request(1, "").unwrap(), None);
        assert_eq!(parse_request(2, "   ").unwrap(), None);
        assert_eq!(parse_request(3, "# comment").unwrap(), None);
    }

    #[test]
    fn malformed_lines_fail_typed_with_line_number() {
        for (lineno, line) in [
            (1, "SCORE"),              // missing argument
            (2, "SCORE notanid"),      // bad ID
            (3, "SCORE 1 2"),          // extra argument
            (4, "RESHARD"),            // missing count
            (5, "RESHARD zero"),       // bad count
            (6, "RESHARD 0"),          // degenerate count
            (7, "STATS now"),          // verb with stray argument
            (8, "QUIT loudly"),        // likewise
            (9, "SHUTDOWN -f"),        // likewise
            (10, "score 42"),          // verbs are case-sensitive → bad update ID
            (11, "42 f0"),             // short update line
            (12, "42 f0 NaN"),         // sketch-poisoning δ
        ] {
            match parse_request(lineno, line) {
                Err(SparxError::InvalidParams(msg)) => {
                    assert!(
                        msg.contains(&format!("line {lineno}")),
                        "line {line:?}: message must name the line, got {msg:?}"
                    );
                }
                other => panic!("line {line:?} must fail typed, got {other:?}"),
            }
        }
    }

    #[test]
    fn verb_and_update_grammars_are_disjoint() {
        // a numeric first token is always an update, never a verb
        assert!(matches!(
            parse_request(1, "100 SCORE 1.0").unwrap(),
            Some(Request::Update(_))
        ));
    }
}
