//! The TCP line grammar: what one `\n`-terminated request line may say.
//!
//! Data lines are **exactly** the `sparx serve --updates` grammar
//! ([`parse_update_line`]): `ID FEATURE δ` or `ID FEATURE old->new`,
//! blank lines and `#` comments skipped — a file that drives the stdin
//! path drives a socket unchanged, and scores come out bit-identical.
//! Control verbs are distinguishable on the first token alone: an
//! update line starts with a numeric ID, a verb with an upper-case
//! keyword, so neither grammar can shadow the other.
//!
//! ```text
//! SCORE <id>                → SCORE <id> <score-bits-hex> | UNKNOWN <id>
//! SCORE <id> <name>         → SCORE <id> <name> <score-bits-hex> | UNKNOWN <id> <name>
//! QUERY ADD <name> <hl> <w> → OK query <name>
//! QUERY DROP <name>         → OK query <name>
//! QUERY LIST                → QUERIES {…one JSON line…}
//! STATS                     → STATS {…one JSON line…}
//! METRICS                   → text-format metrics dump, terminated by `# EOF`
//! CHECKPOINT                → OK checkpoint <submitted>
//! RESHARD <n>               → OK reshard <n>
//! QUIT                      → OK bye, server closes this connection
//! SHUTDOWN                  → OK shutdown, server stops accepting and exits
//! <update line>             → OK <id> <score-bits-hex>   (or BUSY <id>)
//! ```
//!
//! `QUERY ADD` registers a named `(half-life, window)` view evaluated
//! over the same ingest stream (see [`crate::sparx::decay`]); `SCORE
//! <id> <name>` probes it. Query names are validated by
//! [`validate_query_name`] — one `[A-Za-z0-9._-]` token, so every name
//! round-trips the whitespace-tokenized grammar without escaping.
//!
//! Malformed lines answer `ERR <reason>` and the connection stays open;
//! lines longer than [`MAX_LINE_BYTES`] are rejected typed the same way
//! (the overflow is discarded up to the next newline). Responses to one
//! ID always arrive in submit order; responses across IDs may
//! interleave (different shards drain independently).

use crate::api::{Result, SparxError};
use crate::data::{parse_update_line, UpdateTriple};
use crate::sparx::decay::validate_query_name;

/// Hard cap on one request line (bytes, excluding the `\n`). A line that
/// exceeds it is rejected with a typed `ERR` — never silently truncated,
/// never buffered unboundedly.
pub const MAX_LINE_BYTES: usize = 8192;

/// One parsed request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// A ⟨ID, F, δ⟩ data line — scored by the owning shard.
    Update(UpdateTriple),
    /// Read-only score probe for a resident ID.
    Score(u64),
    /// Score probe against a named query's decayed/windowed overlay.
    ScoreNamed(u64, String),
    /// Register a named `(half-life, window)` query over the stream.
    QueryAdd { name: String, half_life: u64, window: u64 },
    /// Drop a named query and its accumulated blocks.
    QueryDrop(String),
    /// One-line JSON dump of the registered queries.
    QueryList,
    /// One-line JSON counter dump.
    Stats,
    /// Text-format metrics dump (`# EOF` terminated).
    Metrics,
    /// Cut a checkpoint to the server's configured `--checkpoint-out`.
    Checkpoint,
    /// Live re-shard to `n` worker threads, between batches, lossless.
    Reshard(usize),
    /// Close this connection (after draining its pending replies).
    Quit,
    /// Stop the whole server gracefully.
    Shutdown,
}

/// Parse one request line. Blank lines and `#` comments yield
/// `Ok(None)`; anything malformed is a typed `SparxError::InvalidParams`
/// naming the line number (rendered as an `ERR` response on the wire).
pub fn parse_request(lineno: usize, line: &str) -> Result<Option<Request>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let bad = |what: String| {
        SparxError::InvalidParams(format!("request line {lineno}: {what}"))
    };
    let toks: Vec<&str> = line.split_whitespace().collect();
    match toks.as_slice() {
        ["SCORE", id_tok] => {
            let id: u64 = id_tok
                .parse()
                .map_err(|_| bad(format!("SCORE: bad ID {id_tok:?}")))?;
            Ok(Some(Request::Score(id)))
        }
        ["SCORE", id_tok, name] => {
            let id: u64 = id_tok
                .parse()
                .map_err(|_| bad(format!("SCORE: bad ID {id_tok:?}")))?;
            validate_query_name(name).map_err(|e| bad(format!("SCORE: {e}")))?;
            Ok(Some(Request::ScoreNamed(id, name.to_string())))
        }
        ["SCORE", ..] => {
            Err(bad("SCORE takes an ID and optionally one query name".into()))
        }
        ["QUERY", "ADD", name, hl_tok, w_tok] => {
            validate_query_name(name).map_err(|e| bad(format!("QUERY ADD: {e}")))?;
            let half_life: u64 = hl_tok
                .parse()
                .map_err(|_| bad(format!("QUERY ADD: bad half-life {hl_tok:?}")))?;
            let window: u64 = w_tok
                .parse()
                .map_err(|_| bad(format!("QUERY ADD: bad window {w_tok:?}")))?;
            Ok(Some(Request::QueryAdd { name: name.to_string(), half_life, window }))
        }
        ["QUERY", "DROP", name] => {
            validate_query_name(name).map_err(|e| bad(format!("QUERY DROP: {e}")))?;
            Ok(Some(Request::QueryDrop(name.to_string())))
        }
        ["QUERY", "LIST"] => Ok(Some(Request::QueryList)),
        ["QUERY", ..] => Err(bad(
            "QUERY subverbs: ADD <name> <half-life> <window> | DROP <name> | LIST".into(),
        )),
        ["RESHARD", n_tok] => {
            let n: usize = n_tok
                .parse()
                .map_err(|_| bad(format!("RESHARD: bad shard count {n_tok:?}")))?;
            if n == 0 {
                return Err(bad("RESHARD: shard count must be ≥ 1".into()));
            }
            Ok(Some(Request::Reshard(n)))
        }
        ["RESHARD", ..] => {
            Err(bad("RESHARD takes exactly one argument (the shard count)".into()))
        }
        [verb @ ("STATS" | "METRICS" | "CHECKPOINT" | "QUIT" | "SHUTDOWN"), rest @ ..] => {
            if !rest.is_empty() {
                return Err(bad(format!("{verb} takes no arguments")));
            }
            Ok(Some(match *verb {
                "STATS" => Request::Stats,
                "METRICS" => Request::Metrics,
                "CHECKPOINT" => Request::Checkpoint,
                "QUIT" => Request::Quit,
                _ => Request::Shutdown,
            }))
        }
        _ => {
            // not a verb: the whole line must be an update triple (its
            // first token is a numeric ID, so the grammars are disjoint;
            // parse_update_line produces the typed error otherwise)
            Ok(parse_update_line(lineno, line)?.map(Request::Update))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verbs_parse() {
        assert_eq!(parse_request(1, "SCORE 42").unwrap(), Some(Request::Score(42)));
        assert_eq!(parse_request(1, "STATS").unwrap(), Some(Request::Stats));
        assert_eq!(parse_request(1, "METRICS").unwrap(), Some(Request::Metrics));
        assert_eq!(parse_request(1, "CHECKPOINT").unwrap(), Some(Request::Checkpoint));
        assert_eq!(parse_request(1, "RESHARD 4").unwrap(), Some(Request::Reshard(4)));
        assert_eq!(parse_request(1, "QUIT").unwrap(), Some(Request::Quit));
        assert_eq!(parse_request(1, "SHUTDOWN").unwrap(), Some(Request::Shutdown));
        assert_eq!(parse_request(1, "  QUIT  ").unwrap(), Some(Request::Quit));
    }

    #[test]
    fn query_verbs_parse() {
        assert_eq!(
            parse_request(1, "SCORE 42 decayed.1k").unwrap(),
            Some(Request::ScoreNamed(42, "decayed.1k".into()))
        );
        assert_eq!(
            parse_request(1, "QUERY ADD w-256 0 256").unwrap(),
            Some(Request::QueryAdd { name: "w-256".into(), half_life: 0, window: 256 })
        );
        assert_eq!(
            parse_request(1, "QUERY DROP w-256").unwrap(),
            Some(Request::QueryDrop("w-256".into()))
        );
        assert_eq!(parse_request(1, "QUERY LIST").unwrap(), Some(Request::QueryList));
    }

    #[test]
    fn update_lines_delegate_to_the_stream_grammar() {
        match parse_request(3, "42 bytes_sent 1.5").unwrap() {
            Some(Request::Update(UpdateTriple::Num { id, feature, delta })) => {
                assert_eq!((id, feature.as_str(), delta), (42, "bytes_sent", 1.5));
            }
            other => panic!("expected an update, got {other:?}"),
        }
        match parse_request(4, "7 loc NYC->Austin").unwrap() {
            Some(Request::Update(UpdateTriple::Cat { id, .. })) => assert_eq!(id, 7),
            other => panic!("expected a categorical update, got {other:?}"),
        }
    }

    #[test]
    fn comments_and_blanks_are_skipped() {
        assert_eq!(parse_request(1, "").unwrap(), None);
        assert_eq!(parse_request(2, "   ").unwrap(), None);
        assert_eq!(parse_request(3, "# comment").unwrap(), None);
    }

    #[test]
    fn malformed_lines_fail_typed_with_line_number() {
        for (lineno, line) in [
            (1, "SCORE"),               // missing argument
            (2, "SCORE notanid"),       // bad ID
            (3, "SCORE 1 2 3"),         // too many arguments
            (4, "RESHARD"),             // missing count
            (5, "RESHARD zero"),        // bad count
            (6, "RESHARD 0"),           // degenerate count
            (7, "STATS now"),           // verb with stray argument
            (8, "QUIT loudly"),         // likewise
            (9, "SHUTDOWN -f"),         // likewise
            (10, "score 42"),           // verbs are case-sensitive → bad update ID
            (11, "42 f0"),              // short update line
            (12, "42 f0 NaN"),          // sketch-poisoning δ
            (13, "SCORE 1 bad name"),   // ScoreNamed arity (name can't have spaces)
            (14, "SCORE 1 emoji✓"),     // hostile query name
            (15, "QUERY"),              // bare QUERY
            (16, "QUERY ADD"),          // missing everything
            (17, "QUERY ADD q 4"),      // missing window
            (18, "QUERY ADD q x 4"),    // bad half-life
            (19, "QUERY ADD q 4 y"),    // bad window
            (20, "QUERY ADD a->b 4 4"), // hostile name
            (21, "QUERY DROP"),         // missing name
            (22, "QUERY DROP a b"),     // extra token
            (23, "QUERY LIST all"),     // extra token
            (24, "QUERY FROB q"),       // unknown subverb
        ] {
            match parse_request(lineno, line) {
                Err(SparxError::InvalidParams(msg)) => {
                    assert!(
                        msg.contains(&format!("line {lineno}")),
                        "line {line:?}: message must name the line, got {msg:?}"
                    );
                }
                other => panic!("line {line:?} must fail typed, got {other:?}"),
            }
        }
    }

    #[test]
    fn verb_and_update_grammars_are_disjoint() {
        // a numeric first token is always an update, never a verb
        assert!(matches!(
            parse_request(1, "100 SCORE 1.0").unwrap(),
            Some(Request::Update(_))
        ));
        // and a query name that happens to be numeric still parses as
        // ScoreNamed — the verb position disambiguates
        assert_eq!(
            parse_request(2, "SCORE 1 7").unwrap(),
            Some(Request::ScoreNamed(1, "7".into()))
        );
    }
}
