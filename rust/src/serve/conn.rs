//! Per-connection plumbing: one reader thread (this function) plus one
//! writer thread per accepted socket.
//!
//! The reader owns line framing (with the [`MAX_LINE_BYTES`] cap),
//! parses each request and drives the shared engine; score replies
//! travel from the shard workers through an **unbounded** per-connection
//! channel to the writer thread, so a worker never blocks on a slow
//! consumer. What bounds a slow consumer instead is the connection's
//! **pending window**: the reader stops pulling new requests while
//! [`PENDING_WINDOW`] replies are still unwritten, which stalls only
//! this client's TCP stream — every other connection and every shard
//! keeps flowing. Error responses (`ERR`, `BUSY`) are written by the
//! reader directly; the socket is mutex-guarded so lines never
//! interleave mid-line.
//!
//! Close protocol (`QUIT`, `SHUTDOWN`, or the client half-closing its
//! send side): the reader flushes the engine, waits for the window to
//! drain — every accepted update still gets its reply, which is what
//! makes a half-closed socket a *graceful* way to end a batch — then
//! closes the channel so the writer exits, and shuts the socket down.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};

use crate::sparx::sharded::ShardReply;

use super::server::{lock, metrics_text, queries_json, stats_json, Shared};
use super::wire::{parse_request, Request, MAX_LINE_BYTES};

/// Max unwritten replies per connection before the reader stops pulling
/// new requests (per-connection backpressure; see the module docs).
pub const PENDING_WINDOW: usize = 1024;

/// Bytes pulled from the socket per `read()`.
const READ_CHUNK: usize = 4096;

/// The reply-window accounting shared by a connection's reader and
/// writer threads.
struct Window {
    state: Mutex<WindowState>,
    cv: Condvar,
}

struct WindowState {
    in_flight: usize,
    /// The writer hit a dead socket: stop waiting on this window, the
    /// replies have nowhere to go.
    dead: bool,
}

impl Window {
    fn new() -> Window {
        Window { state: Mutex::new(WindowState { in_flight: 0, dead: false }), cv: Condvar::new() }
    }

    /// Block until a reply slot is free. Returns false when the writer
    /// declared the connection dead.
    fn acquire(&self) -> bool {
        let mut st = lock(&self.state);
        while st.in_flight >= PENDING_WINDOW && !st.dead {
            st = self.cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        if st.dead {
            return false;
        }
        st.in_flight += 1;
        true
    }

    /// Writer-side: one reply left the process.
    fn complete(&self) {
        let mut st = lock(&self.state);
        st.in_flight = st.in_flight.saturating_sub(1);
        self.cv.notify_all();
    }

    /// Writer-side: the socket died — unblock the reader for good.
    fn kill(&self) {
        lock(&self.state).dead = true;
        self.cv.notify_all();
    }

    /// Block until every accepted request has been answered (or the
    /// connection died).
    fn drain(&self) {
        let mut st = lock(&self.state);
        while st.in_flight > 0 && !st.dead {
            st = self.cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

/// Write one response line. Returns false once the socket is gone (the
/// caller stops producing — responses are never silently dropped while
/// the socket lives).
fn write_line(sock: &Mutex<TcpStream>, line: &str) -> bool {
    let mut s = lock(sock);
    s.write_all(line.as_bytes()).and_then(|()| s.write_all(b"\n")).is_ok()
}

/// The writer thread: drain score replies to the socket in channel
/// order (per-ID submit order is preserved end to end — same ID → same
/// shard → FIFO queue → FIFO reply channel). On a dead socket it keeps
/// draining the channel so the window empties and the reader unblocks.
fn writer_loop(rx: Receiver<ShardReply>, sock: Arc<Mutex<TcpStream>>, window: Arc<Window>) {
    let mut alive = true;
    while let Ok(reply) = rx.recv() {
        if alive {
            let line = match reply {
                ShardReply::Update(score) => {
                    format!("OK {} {:016x}", score.id, score.outlierness.to_bits())
                }
                ShardReply::Query { id, score: Some(x) } => {
                    format!("SCORE {id} {:016x}", x.to_bits())
                }
                ShardReply::Query { id, score: None } => format!("UNKNOWN {id}"),
                ShardReply::QueryNamed { id, name, score: Some(x) } => {
                    format!("SCORE {id} {name} {:016x}", x.to_bits())
                }
                ShardReply::QueryNamed { id, name, score: None } => {
                    format!("UNKNOWN {id} {name}")
                }
            };
            if !write_line(&sock, &line) {
                alive = false;
                window.kill();
            }
        }
        window.complete();
    }
}

/// Line framer over the raw socket: maintains the partial-line buffer
/// and the oversized-line skip state.
struct LineBuf {
    buf: Vec<u8>,
    /// Inside an oversized line: discard bytes until the next newline.
    skipping: bool,
    lineno: usize,
}

enum Framed {
    /// A complete line, tagged with its 1-based line number.
    Line(usize, String),
    /// An oversized line was rejected (the typed error to send).
    TooLong(usize),
}

impl LineBuf {
    fn new() -> LineBuf {
        LineBuf { buf: Vec::new(), skipping: false, lineno: 0 }
    }

    /// Append a chunk and pop complete lines / oversize rejections.
    fn push(&mut self, chunk: &[u8]) -> Vec<Framed> {
        self.buf.extend_from_slice(chunk);
        let mut out = Vec::new();
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                let rest = self.buf.split_off(pos + 1);
                let mut line = std::mem::replace(&mut self.buf, rest);
                line.pop(); // the newline
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                if self.skipping {
                    // tail of a line already rejected as oversized
                    self.skipping = false;
                    continue;
                }
                self.lineno += 1;
                if line.len() > MAX_LINE_BYTES {
                    out.push(Framed::TooLong(self.lineno));
                    continue;
                }
                out.push(Framed::Line(self.lineno, String::from_utf8_lossy(&line).into_owned()));
            } else {
                // no complete line: reject an over-long prefix *now* so
                // the buffer never grows unboundedly
                if !self.skipping && self.buf.len() > MAX_LINE_BYTES {
                    self.lineno += 1;
                    self.skipping = true;
                    self.buf.clear();
                    out.push(Framed::TooLong(self.lineno));
                }
                return out;
            }
        }
    }
}

/// Serve one accepted connection (the reader thread body).
pub(crate) fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    let Ok(write_half) = stream.try_clone() else {
        return;
    };
    let sock = Arc::new(Mutex::new(write_half));
    let window = Arc::new(Window::new());
    let (reply_tx, reply_rx) = channel::<ShardReply>();
    let writer = {
        let sock = sock.clone();
        let window = window.clone();
        std::thread::spawn(move || writer_loop(reply_rx, sock, window))
    };

    let mut read_half = stream;
    let mut frames = LineBuf::new();
    let mut chunk = vec![0u8; READ_CHUNK];
    let mut alive = true;
    let mut shutdown_requested = false;
    'read: while alive {
        let n = match read_half.read(&mut chunk) {
            Ok(0) => break, // EOF (client closed or half-closed its send side)
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        };
        let got = chunk.get(..n).unwrap_or_default();
        let mut submitted_any = false;
        for framed in frames.push(got) {
            let (lineno, line) = match framed {
                Framed::Line(lineno, line) => (lineno, line),
                Framed::TooLong(lineno) => {
                    alive &= write_line(
                        &sock,
                        &format!(
                            "ERR request line {lineno} exceeds {MAX_LINE_BYTES} bytes \
                             (rejected, not truncated)"
                        ),
                    );
                    continue;
                }
            };
            let req = match parse_request(lineno, &line) {
                Ok(Some(req)) => req,
                Ok(None) => continue, // blank / comment
                Err(e) => {
                    alive &= write_line(&sock, &format!("ERR {e}"));
                    continue;
                }
            };
            match req {
                Request::Update(u) => {
                    if !window.acquire() {
                        break 'read; // writer declared the socket dead
                    }
                    let outcome = lock(&shared.engine).try_submit(u, reply_tx.clone());
                    match outcome {
                        Ok(Ok(())) => submitted_any = true,
                        Ok(Err(would_block)) => {
                            // not accepted → no reply will come: release
                            // the slot and surface the backpressure
                            window.complete();
                            alive &=
                                write_line(&sock, &format!("BUSY {}", would_block.0.id()));
                        }
                        Err(e) => {
                            window.complete();
                            alive &= write_line(&sock, &format!("ERR {e}"));
                        }
                    }
                }
                Request::Score(id) => {
                    if !window.acquire() {
                        break 'read;
                    }
                    if let Err(e) = lock(&shared.engine).query(id, reply_tx.clone()) {
                        window.complete();
                        alive &= write_line(&sock, &format!("ERR {e}"));
                    }
                }
                Request::ScoreNamed(id, name) => {
                    if !window.acquire() {
                        break 'read;
                    }
                    if let Err(e) = lock(&shared.engine).query_named(id, &name, reply_tx.clone())
                    {
                        window.complete();
                        alive &= write_line(&sock, &format!("ERR {e}"));
                    }
                }
                Request::QueryAdd { name, half_life, window: win } => {
                    // registration is feeder-side bookkeeping under the
                    // engine lock; it never forces an epoch publish, so
                    // the primary score sequence is unaffected
                    let line = match lock(&shared.engine).query_add(&name, half_life, win) {
                        Ok(()) => format!("OK query {name}"),
                        Err(e) => format!("ERR {e}"),
                    };
                    alive &= write_line(&sock, &line);
                }
                Request::QueryDrop(name) => {
                    let line = match lock(&shared.engine).query_drop(&name) {
                        Ok(()) => format!("OK query {name}"),
                        Err(e) => format!("ERR {e}"),
                    };
                    alive &= write_line(&sock, &line);
                }
                Request::QueryList => {
                    let line = match lock(&shared.engine).query_list() {
                        Ok(queries) => {
                            format!("QUERIES {{\"queries\":{}}}", queries_json(&queries))
                        }
                        Err(e) => format!("ERR {e}"),
                    };
                    alive &= write_line(&sock, &line);
                }
                Request::Stats => {
                    let line = match lock(&shared.engine).stats() {
                        Ok(stats) => format!("STATS {}", stats_json(&stats)),
                        Err(e) => format!("ERR {e}"),
                    };
                    alive &= write_line(&sock, &line);
                }
                Request::Metrics => {
                    let text = match lock(&shared.engine).stats() {
                        Ok(stats) => metrics_text(&stats),
                        Err(e) => format!("ERR {e}\n"),
                    };
                    let mut s = lock(&sock);
                    alive &= s.write_all(text.as_bytes()).is_ok();
                }
                Request::Checkpoint => {
                    let line = match lock(&shared.engine).checkpoint() {
                        Ok(submitted) => format!("OK checkpoint {submitted}"),
                        Err(e) => format!("ERR {e}"),
                    };
                    alive &= write_line(&sock, &line);
                }
                Request::Reshard(n) => {
                    // the engine lock holds all other submitters at the
                    // batch boundary while the barrier + respawn runs
                    let line = match lock(&shared.engine).reshard(n) {
                        Ok(shards) => format!("OK reshard {shards}"),
                        Err(e) => format!("ERR {e}"),
                    };
                    alive &= write_line(&sock, &line);
                }
                Request::Quit => {
                    alive &= write_line(&sock, "OK bye");
                    break 'read;
                }
                Request::Shutdown => {
                    shutdown_requested = true;
                    alive &= write_line(&sock, "OK shutdown");
                    break 'read;
                }
            }
        }
        if submitted_any {
            // one flush per read chunk: batches reach the shards and
            // replies materialize even when the client now goes quiet
            let _ = lock(&shared.engine).flush();
        }
    }

    // graceful close: everything accepted still gets its reply
    let _ = lock(&shared.engine).flush();
    window.drain();
    drop(reply_tx); // writer exits once in-flight reply clones drop too
    let _ = writer.join();
    let _ = lock(&sock).shutdown(Shutdown::Both);
    if shutdown_requested {
        // trip the latch only after this connection drained, so the
        // accept loop's force-close cannot cut our own tail off
        shared.request_shutdown();
    }
}
