//! The elastic serving plane: a zero-dependency TCP ingress in front of
//! the sharded scorer (`sparx serve --listen ADDR`).
//!
//! ```text
//!  client ──┐                       ┌── reader thread ──┐   try_submit   ┌─ shard 0 ─┐
//!  client ──┼── accept loop ── conn ┤                   ├── Mutex<Engine>┼─ shard 1 ─┤
//!  client ──┘   ([`Server`])        └── writer thread ──┘   (seq order)  └─ shard N ─┘
//!                                        ▲   unbounded reply channel          │
//!                                        └──────────────────────── ShardReply ┘
//! ```
//!
//! * **Ingress** — each accepted socket gets a reader thread (line
//!   framing with an 8 KiB cap, [`wire`] grammar: the exact
//!   `parse_update_line` data lines plus the `SCORE` / `QUERY
//!   ADD|DROP|LIST` / `STATS` / `METRICS` / `CHECKPOINT` / `RESHARD` /
//!   `QUIT` / `SHUTDOWN` control verbs) and a writer thread draining
//!   that connection's reply channel.
//! * **Ordering** — submit sequence numbers are assigned under the one
//!   [`Engine`] mutex, so the global stream order is as well-defined
//!   under N concurrent clients as under one stdin reader; per-ID
//!   replies arrive in submit order (same ID → same shard → FIFO).
//! * **Backpressure, never loss** — a full shard queue answers `BUSY`
//!   (typed, the update was not accepted) via the scorer's `try_submit`;
//!   a slow *consumer* is bounded by the per-connection pending window
//!   (the reader stops pulling new requests while too many replies are
//!   unwritten), which stalls only that client: shard workers reply
//!   through unbounded channels and never block.
//! * **Elasticity** — `RESHARD N` runs the scorer's drain-to-barrier →
//!   snapshot → re-partition → respawn under the engine lock, between
//!   batches, dropping nothing; `CHECKPOINT` cuts the layout-independent
//!   v5 absorb checkpoint (decay blocks and named queries included),
//!   so a later `serve --resume` may pick any `--shards`/`--cache` and
//!   continue bit-identically.
//! * **Multi-query** — `QUERY ADD <name> <half-life> <window>` registers
//!   a named decayed/windowed view over the same ingest stream; `SCORE
//!   <id> <name>` probes it and `QUERY LIST` dumps per-query counters.
//!   Registration is feeder-side only and never moves the primary score
//!   sequence (see [`crate::sparx::decay`]).
//! * **Shutdown** — `SHUTDOWN` drains its own connection, trips the
//!   server latch and wakes the accept loop; remaining sockets are
//!   closed, their connections drained, and [`Server::run`] hands the
//!   scorer back for the same finalization path stdin serving uses.
//!
//! See ARCHITECTURE.md ("Serving plane") for the wire grammar spec and
//! the re-shard barrier protocol in full.

mod conn;
mod server;
pub mod wire;

pub use conn::PENDING_WINDOW;
pub use server::{metrics_text, queries_json, stats_json, Engine, Server};
pub use wire::{parse_request, Request, MAX_LINE_BYTES};
