//! SpamURL-scale scenario (paper §4.2.5): sparse, very high-dimensional
//! data where outliers hide in small subspaces.
//!
//! Demonstrates the property the baselines lack: Sparx consumes the raw
//! sparse rows directly via hash projection (Eq. 2) — no densification —
//! while SPIF needs a dense K=100 projection of the data first.
//!
//! Run: `cargo run --release --example spamurl_detection [n]`

use sparx::baselines::{Spif, SpifParams};
use sparx::config::presets;
use sparx::data::generators::SpamUrlGen;
use sparx::data::{Dataset, Row, Schema};
use sparx::experiments::align_scores;
use sparx::metrics::{RankMetrics, ResourceReport};
use sparx::sparx::{project_dataset, Projector, SparxModel, SparxParams};

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let gen = SpamUrlGen { n, ..Default::default() };

    // --- Sparx directly on sparse rows
    {
        let mut ctx = presets::config_mod().build();
        let ld = gen.generate(&ctx).unwrap();
        println!(
            "SpamURL-like: n={} d={} (sparse), outliers {:.1}%",
            ld.dataset.len(),
            ld.dataset.dim(),
            100.0 * ld.outlier_rate()
        );
        ctx.reset();
        let p = SparxParams {
            k: 100,
            num_chains: 50,
            depth: 10,
            sample_rate: 0.1,
            ..Default::default()
        };
        let model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
        let scores = model.score_dataset(&ctx, &ld.dataset).unwrap();
        let met = RankMetrics::compute(&align_scores(&scores, ld.labels.len()), &ld.labels);
        println!(
            "\nSparx  K=100 M=50 L=10 (raw sparse input): AUROC={:.3} AUPRC={:.3} F1={:.3}",
            met.auroc, met.auprc, met.f1
        );
        println!("  {}", ResourceReport::from_ctx(&ctx).summary());
    }

    // --- SPIF needs densification first (the paper had to do the same)
    {
        let mut ctx = presets::config_mod().build();
        let ld = gen.generate(&ctx).unwrap();
        let projector = Projector::new(100, 1.0 / 3.0);
        let proj = project_dataset(&ctx, &ld.dataset, &projector).unwrap();
        let dense_rows = proj.map(&ctx, |sk| Row::dense(sk.id, sk.s.clone())).unwrap();
        let dense = Dataset::new(Schema::positional(100), dense_rows);
        ctx.reset();
        let p = SpifParams { num_trees: 50, max_depth: 10, sample_rate: 0.1, ..Default::default() };
        let model = Spif::fit(&ctx, &dense, &p).unwrap();
        let scores = model.score_dataset(&ctx, &dense).unwrap();
        let met = RankMetrics::compute(&align_scores(&scores, ld.labels.len()), &ld.labels);
        println!(
            "\nSPIF   d=100 projection (cannot ingest sparse): AUROC={:.3} AUPRC={:.3} F1={:.3}",
            met.auroc, met.auprc, met.f1
        );
        println!("  {}", ResourceReport::from_ctx(&ctx).summary());
    }
}
