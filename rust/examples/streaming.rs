//! Evolving-stream deployment (paper §3.5, Problem 2).
//!
//! A fitted model is deployed behind a single front-end node; ⟨ID, F, δ⟩
//! update triples stream in — numeric increments, categorical
//! substitutions, and **brand-new features** that did not exist at
//! training time (the "not to cash, but to hash" property). Each update
//! costs O(K) to apply and O(rLM) to rescore; memory is bounded by the
//! LRU cache of sketches.
//!
//! Run: `cargo run --release --example streaming [num_updates]`

use std::sync::Arc;

use sparx::config::presets;
use sparx::data::generators::GisetteGen;
use sparx::data::{StreamGen, UpdateTriple};
use sparx::sparx::{
    ServeOptions, ServedEnsemble, ShardedStreamScorer, SparxModel, SparxParams, StreamScorer,
};

fn main() {
    let updates: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(50_000);

    // fit offline on the batch data
    let ctx = presets::config_local().build();
    let ld = GisetteGen { n: 2000, d: 128, ..Default::default() }.generate(&ctx).unwrap();
    let model = SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 25, num_chains: 25, depth: 10, ..Default::default() },
    )
    .unwrap();
    println!(
        "model fitted: M={} L={} K={} ({} bytes — the whole deployment state)",
        model.params.num_chains,
        model.params.depth,
        model.params.k,
        model.model_bytes()
    );

    // deploy
    let mut scorer = StreamScorer::new(&model, 4096).unwrap();
    let mut gen = StreamGen::new(10_000, ld.dataset.schema.names.clone(), 0xFEED);
    gen.new_feature_rate = 0.02;

    let t0 = std::time::Instant::now();
    let mut new_feature_updates = 0u64;
    let mut alerts = 0u64;
    let mut worst_score = f64::NEG_INFINITY;
    let mut worst_id = 0;
    for i in 0..updates {
        let u = gen.next_update();
        if u.feature().starts_with("new_indicator") {
            new_feature_updates += 1;
        }
        let s = scorer.update(&u);
        if s.outlierness > worst_score {
            worst_score = s.outlierness;
            worst_id = s.id;
        }
        // alert on extreme scores (simple fixed threshold for the demo)
        if s.outlierness > -2.0 {
            alerts += 1;
        }
        if i % 10_000 == 0 && i > 0 {
            println!("  {i} updates… ({:.0}/s)", i as f64 / t0.elapsed().as_secs_f64());
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\n{updates} δ-updates in {dt:.2}s — {:.0} updates/s (constant per-update cost)",
        updates as f64 / dt
    );
    println!(
        "  {} updates touched features unseen at training time",
        new_feature_updates
    );
    println!("  cache: {} ids, {} evictions", scorer.cached_ids(), scorer.evictions());
    println!("  alerts: {alerts}; most outlying id: {worst_id} (score {worst_score:.3})");

    // categorical walk-through (Eq. 3's substitution form)
    let mut s1 = scorer.update(&UpdateTriple::Cat {
        id: 424242,
        feature: "loc".into(),
        old: None,
        new: "NYC".into(),
    });
    println!("\ncustomer 424242 appears in NYC          → score {:.3}", s1.outlierness);
    s1 = scorer.update(&UpdateTriple::Cat {
        id: 424242,
        feature: "loc".into(),
        old: Some("NYC".into()),
        new: "Austin".into(),
    });
    println!("customer 424242 relocates NYC → Austin  → score {:.3}", s1.outlierness);

    // scale out: the same evolving stream through the sharded front-end —
    // murmur(ID) % S routes every update to a pinned shard worker with
    // its own LRU, while every shard scores against ONE Arc-shared
    // read-only ensemble (1x resident model, any S); each shard scores
    // bit-identically to a single-threaded scorer fed its sub-stream
    // while throughput scales with the cores
    let shards = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(2, 8);
    // a fresh generator with the identical seed/config replays exactly
    // the update sequence the single-threaded loop above consumed, and
    // 4096/shards keeps the total cache budget equal — the speedup
    // factor below compares the same workload end to end
    let mut gen = StreamGen::new(10_000, ld.dataset.schema.names.clone(), 0xFEED);
    gen.new_feature_rate = 0.02;
    let ensemble = Arc::new(ServedEnsemble::new(&model).unwrap());
    println!(
        "\nshared serving ensemble: {} bytes resident — held once for any shard count",
        ensemble.resident_bytes()
    );
    let mut sharded = ShardedStreamScorer::from_ensemble(
        ensemble.clone(),
        ServeOptions::default().shards(shards).cache(4096 / shards),
        None,
    )
    .unwrap();
    let t0 = std::time::Instant::now();
    for _ in 0..updates {
        sharded.submit(gen.next_update());
    }
    // cut a durable checkpoint of the mutable half (LRU sketches +
    // absorbed deltas + counters) — what `sparx serve --checkpoint-out`
    // writes and `--resume` restores bit-identically
    let checkpoint = sharded.checkpoint().unwrap();
    let report = sharded.finish();
    let dt2 = t0.elapsed().as_secs_f64();
    println!(
        "sharded front-end (S={shards}): {} δ-updates in {dt2:.2}s — {:.0} updates/s \
         ({:.2}x the single-threaded rate)",
        report.processed(),
        report.processed() as f64 / dt2,
        (report.processed() as f64 / dt2) / (updates as f64 / dt)
    );
    for (i, c) in report.shards.iter().enumerate() {
        println!(
            "  shard {i}: {} updates, {} resident sketches, {} evictions",
            c.processed, c.cached_ids, c.evictions
        );
    }
    // a "restarted" deployment restores the checkpoint and continues the
    // stream exactly where the first process left off
    let mut resumed = ShardedStreamScorer::from_ensemble(
        ensemble,
        ServeOptions::default().shards(shards).cache(4096 / shards),
        Some(&checkpoint),
    )
    .unwrap();
    resumed.submit(gen.next_update());
    let resumed_report = resumed.finish();
    println!(
        "checkpoint → resume: {} sketches restored across {shards} shards, stream \
         continued at update #{}",
        checkpoint.merged().entries.len(),
        resumed_report.processed()
    );
}
