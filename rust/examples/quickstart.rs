//! Quickstart: the end-to-end driver proving all layers compose.
//!
//! 1. generates the Gisette-like benchmark (GMM protocol, 10% outliers);
//! 2. fits Sparx with the two-pass distributed algorithm on the
//!    shared-nothing cluster substrate — through **both** binning
//!    backends: native Rust and the AOT Pallas kernels via PJRT;
//! 3. verifies the backends agree, reports AUROC/AUPRC/F1 + resources;
//! 4. runs a few evolving-stream δ-updates through the §3.5 front-end.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use sparx::config::presets;
use sparx::data::generators::GisetteGen;
use sparx::data::UpdateTriple;
use sparx::experiments::align_scores;
use sparx::metrics::{RankMetrics, ResourceReport};
use sparx::runtime::{PjrtBinner, PjrtEngine};
use sparx::sparx::{project_dataset, SparxModel, SparxParams, StreamScorer};

fn main() {
    // --- a scaled Gisette (small-n / large-d, 10% planted outliers)
    let mut ctx = presets::config_local().build();
    let ld = GisetteGen { n: 4000, d: 512, ..Default::default() }.generate(&ctx).unwrap();
    println!(
        "dataset: n={} d={} outliers={} ({:.1}%)",
        ld.dataset.len(),
        ld.dataset.dim(),
        ld.outlier_count(),
        100.0 * ld.outlier_rate()
    );
    ctx.reset();

    // --- fit + score, native backend
    let params = SparxParams {
        k: 50,
        num_chains: 50,
        depth: 10,
        sample_rate: 0.1,
        ..Default::default()
    };
    let model = SparxModel::fit(&ctx, &ld.dataset, &params).unwrap();
    let scores = model.score_dataset(&ctx, &ld.dataset).unwrap();
    let met = RankMetrics::compute(&align_scores(&scores, ld.labels.len()), &ld.labels);
    println!(
        "\nSparx[native]  AUROC={:.3} AUPRC={:.3} F1={:.3}",
        met.auroc, met.auprc, met.f1
    );
    println!("  {}", ResourceReport::from_ctx(&ctx).summary());
    println!("  model size: {} bytes (O(M·L·r·w), constant in n)", model.model_bytes());

    // --- same scoring through the AOT Pallas artifacts on PJRT
    match PjrtEngine::start_default() {
        Ok(engine) => {
            let binner = PjrtBinner { engine: &engine, variant: "gisette".into() };
            let proj = project_dataset(&ctx, &ld.dataset, &model.projector).unwrap();
            let pjrt_scores = model.score_sketches_with(&ctx, &proj, &binner).unwrap();
            let met2 =
                RankMetrics::compute(&align_scores(&pjrt_scores, ld.labels.len()), &ld.labels);
            let max_dev = scores
                .iter()
                .zip(&pjrt_scores)
                .map(|((_, a), (_, b))| (a - b).abs())
                .fold(0.0f64, f64::max);
            println!(
                "Sparx[pjrt]    AUROC={:.3} (max score deviation vs native: {max_dev:.2e})",
                met2.auroc
            );
            assert!(max_dev < 1e-6, "backends must agree");
        }
        Err(e) => println!("Sparx[pjrt]    skipped ({e}) — run `make artifacts`"),
    }

    // --- §3.5: constant-time updates over an evolving stream
    let mut scorer = StreamScorer::new(&model, 1024).unwrap();
    println!("\nevolving-stream demo (δ-updates, incl. a brand-new feature):");
    for (feature, delta) in
        [("f10", 0.5), ("f10", -0.2), ("brand_new_indicator", 4.0), ("f99", 0.1)]
    {
        let s = scorer.update(&UpdateTriple::Num {
            id: 7,
            feature: feature.into(),
            delta,
        });
        println!("  <7, {feature}, {delta:+}> → outlierness {:.3}", s.outlierness);
    }
    println!("\nquickstart OK");
}
