//! The model lifecycle end to end — **fit → save → load → score →
//! serve** (§3.5's deployment story): train once on the cluster, ship
//! the O(rwLM) artifact to a deployment node, score batches and
//! δ-updates from the loaded model.
//!
//! Run: `cargo run --release --example model_lifecycle`

use sparx::api::{registry, Detector as _, DetectorSpec, FittedModel as _};
use sparx::config::presets;
use sparx::data::generators::GisetteGen;
use sparx::data::UpdateTriple;

fn main() -> sparx::api::Result<()> {
    let cluster = presets::config_local().build();
    let data = GisetteGen { n: 2000, d: 128, ..Default::default() }.generate(&cluster)?;

    // 1. fit on the cluster
    let spec = DetectorSpec {
        k: Some(25),
        components: Some(25),
        depth: Some(8),
        sample_rate: Some(0.2),
        ..Default::default()
    };
    let model = registry::build("sparx", &spec)?.fit(&cluster, &data.dataset)?;

    // 2. save — the versioned artifact is the whole deployment state
    let path = std::env::temp_dir().join("model_lifecycle_demo.sparx");
    let path = path.to_str().expect("utf-8 temp dir").to_string();
    model.to_artifact()?.save(&path)?;
    println!("saved {}B model payload to {path}", model.model_bytes());

    // 3. load on the "deployment node" and score a batch — bit-identical
    //    to scoring the in-memory model
    let loaded = registry::load(&path)?;
    let scores = loaded.score(&cluster, &data.dataset)?;
    let reference = model.score(&cluster, &data.dataset)?;
    assert_eq!(scores, reference, "loaded model must score bit-identically");
    println!("scored {} points from the loaded model", scores.len());

    // 4. serve the evolving stream (§3.5) from the loaded model —
    //    including a feature that did not exist at training time
    let mut scorer = loaded.stream_scorer(1024)?;
    for (feature, delta) in [("f1", 0.4), ("f7", -1.0), ("brand_new_signal", 3.0)] {
        let s = scorer.update(&UpdateTriple::Num { id: 9, feature: feature.into(), delta });
        println!("  <9, {feature}, {delta:+}> -> outlierness {:.3}", s.outlierness);
    }

    let _ = std::fs::remove_file(&path);
    println!("lifecycle OK");
    Ok(())
}
