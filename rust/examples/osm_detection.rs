//! OSM-scale scenario (paper §4.2.4): billions-of-points regime, scaled.
//!
//! Generates the GPS-trace workload with the paper's outlier-injection
//! protocol, runs all three methods, and prints the F1-vs-resources
//! comparison — the Fig. 3 story in one binary.
//!
//! Run: `cargo run --release --example osm_detection [n_inliers]`

use sparx::baselines::dbscout::{Dbscout, DbscoutParams};
use sparx::baselines::{Spif, SpifParams};
use sparx::config::presets;
use sparx::data::generators::OsmGen;
use sparx::experiments::align_scores;
use sparx::metrics::{f1_binary, RankMetrics, ResourceReport};
use sparx::sparx::{SparxModel, SparxParams};

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300_000);
    let gen = OsmGen {
        n_inliers: n,
        n_outliers: (n / 1000).max(50),
        ..Default::default()
    };

    // --- Sparx on raw 2-d coordinates (no projection, as in the paper)
    {
        let mut ctx = presets::config_gen().build();
        let ld = gen.generate(&ctx).unwrap();
        println!("OSM-like: n={} outliers={}", ld.dataset.len(), ld.outlier_count());
        ctx.reset();
        let p = SparxParams { k: 0, num_chains: 10, depth: 10, sample_rate: 0.01, ..Default::default() };
        let model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
        let scores = model.score_dataset(&ctx, &ld.dataset).unwrap();
        let met = RankMetrics::compute(&align_scores(&scores, ld.labels.len()), &ld.labels);
        println!(
            "\nSparx   M=10 L=10 rate=0.01: AUROC={:.3} AUPRC={:.3} F1={:.3}",
            met.auroc, met.auprc, met.f1
        );
        println!("  {}", ResourceReport::from_ctx(&ctx).summary());
    }

    // --- DBSCOUT (binary verdicts; excels at d=2)
    {
        let mut ctx = presets::config_gen().build();
        let ld = gen.generate(&ctx).unwrap();
        ctx.reset();
        let params = DbscoutParams { eps: 0.05, min_pts: 16, ..Default::default() };
        let v = Dbscout::run(&ctx, &ld.dataset, &params).unwrap();
        let mut pred = vec![false; ld.labels.len()];
        for (id, o) in v.pred {
            pred[id as usize] = o;
        }
        println!(
            "\nDBSCOUT eps=0.05 minPts=16: F1={:.3} (binary output only; {} occupied cells, {} dense)",
            f1_binary(&pred, &ld.labels),
            v.occupied_cells,
            v.dense_cells
        );
        println!("  {}", ResourceReport::from_ctx(&ctx).summary());
    }

    // --- SPIF (must fit on a sliver — Table 4)
    {
        let mut ctx = presets::config_gen().build();
        let ld = gen.generate(&ctx).unwrap();
        ctx.reset();
        let p = SpifParams { num_trees: 50, max_depth: 25, sample_rate: 1e-3, ..Default::default() };
        match Spif::fit(&ctx, &ld.dataset, &p).and_then(|m| m.score_dataset(&ctx, &ld.dataset)) {
            Ok(scores) => {
                let met =
                    RankMetrics::compute(&align_scores(&scores, ld.labels.len()), &ld.labels);
                println!(
                    "\nSPIF    50 trees rate=1e-3: AUROC={:.3} AUPRC={:.3} F1={:.3}",
                    met.auroc, met.auprc, met.f1
                );
                println!("  {}", ResourceReport::from_ctx(&ctx).summary());
            }
            Err(e) => println!("\nSPIF    failed as the paper predicts at scale: {e}"),
        }
    }
}
