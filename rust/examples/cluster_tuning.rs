//! Cluster-tuning walkthrough (the Fig. 5 story): how partitioning and
//! worker counts change Sparx's runtime, and where the parallel speed-up
//! against single-machine xStream comes from — plus what the shuffle
//! ledger says about why over-partitioning stops helping.
//!
//! Run: `cargo run --release --example cluster_tuning`

use sparx::baselines::{XStream, XStreamParams};
use sparx::cluster::ClusterConfig;
use sparx::data::generators::GisetteGen;
use sparx::metrics::ResourceReport;
use sparx::sparx::{SparxModel, SparxParams};

fn main() {
    let gen = GisetteGen { n: 6000, d: 256, ..Default::default() };
    let sp = SparxParams { k: 50, num_chains: 10, depth: 5, sample_rate: 1.0, ..Default::default() };

    // single-machine baseline
    let base = ClusterConfig { num_partitions: 1, ..Default::default() }.build();
    let ld = gen.generate(&base).unwrap();
    let rows = ld.dataset.rows.collect(&base).unwrap();
    let xp = XStreamParams {
        k: sp.k,
        num_chains: sp.num_chains,
        depth: sp.depth,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let xs = XStream::fit(&rows, &ld.dataset.schema.names, &xp);
    let _ = xs.score(&rows);
    let xstream_secs = t0.elapsed().as_secs_f64();
    println!("single-machine xStream: {xstream_secs:.2}s\n");
    println!("{:>10} {:>8} {:>9} {:>10} {:>12} {:>9}", "partitions", "workers", "time(s)", "speed-up", "shuffled(KB)", "rounds");

    for &(parts, workers) in
        &[(8usize, 2usize), (8, 8), (32, 8), (64, 8), (128, 8), (256, 8), (256, 2)]
    {
        let mut ctx = ClusterConfig {
            num_partitions: parts,
            num_workers: workers,
            num_threads: workers,
            ..Default::default()
        }
        .build();
        let ld = gen.generate(&ctx).unwrap();
        ctx.reset();
        let model = SparxModel::fit(&ctx, &ld.dataset, &sp).unwrap();
        let _ = model.score_dataset(&ctx, &ld.dataset).unwrap();
        let res = ResourceReport::from_ctx(&ctx);
        println!(
            "{parts:>10} {workers:>8} {:>9.2} {:>9.1}x {:>12.1} {:>9}",
            res.job_secs,
            xstream_secs / res.job_secs,
            res.shuffle_bytes as f64 / 1024.0,
            res.shuffle_rounds
        );
    }
    println!("\nreading the table: speed-up rises with workers; past the sweet");
    println!("spot, more partitions only add scheduling + shuffle overhead");
    println!("(the paper's Fig. 5 observation that speed-up is not monotonic).");
}
