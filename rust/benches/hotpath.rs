//! Micro-benchmarks of the hot paths (harness = false; criterion is not
//! in the offline dependency set, so this uses a small in-file timer with
//! warmup + repetitions + ns/op reporting).
//!
//! Covers the §Perf targets of EXPERIMENTS.md:
//!   * native chain binning (L3 request path, per-point cost)
//!   * multi-chain tiling (the fused executors' binning entry point)
//!   * CMS insert / query
//!   * hash projection (dense memoised R and sparse on-the-fly)
//!   * PJRT tile execution (chain_bins + fused project_bins artifacts)
//!   * distributed fit+score, fused vs per-chain execution plans
//!   * streaming δ-update + rescore
//!   * sharded serve throughput at S = 1, 2, 4, 8 (one fixed update
//!     sequence replayed at every shard count; `-- serve` runs only
//!     this section — CI publishes its lines as the step summary)

use sparx::data::Row;
use sparx::hash::SignHasher;
use sparx::sparx::{ChainParams, CountMinSketch, NativeBinner, Projector};
use sparx::sparx::chain::Binner;
use sparx::util::Rng;

fn bench<F: FnMut() -> u64>(name: &str, items_per_iter: u64, mut f: F) {
    // warmup
    let mut sink = 0u64;
    for _ in 0..3 {
        sink = sink.wrapping_add(f());
    }
    let mut iters = 0u64;
    let t0 = std::time::Instant::now();
    while t0.elapsed().as_secs_f64() < 1.0 {
        sink = sink.wrapping_add(f());
        iters += 1;
    }
    let total = t0.elapsed().as_secs_f64();
    let per_item = total / (iters as f64 * items_per_iter as f64);
    println!(
        "{name:<44} {:>10.1} ns/item  ({:>8.2} Mitems/s)  [sink {sink}]",
        per_item * 1e9,
        1e-6 / per_item
    );
}

fn main() {
    // `cargo bench --bench hotpath -- serve` runs only the serve-throughput
    // section (what the CI step summary publishes). Match anywhere in
    // argv: cargo inserts its own `--bench` flag ahead of passthrough
    // args even for harness = false targets.
    if std::env::args().any(|a| a == "serve") {
        serve_throughput();
        println!("done");
        return;
    }
    let mut rng = Rng::new(7);
    println!("== sparx hot-path microbenches ==");

    // --- chain binning (K=50, L=20, tile of 256) — the scoring hot loop
    let k = 50;
    let l = 20;
    let n = 256;
    let delta: Vec<f32> = (0..k).map(|_| rng.range_f64(0.5, 2.0) as f32).collect();
    let chain = ChainParams::sample(&delta, l, &mut rng);
    let s: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
    bench("native tile_bins K=50 L=20 (per point)", n as u64, || {
        NativeBinner.tile_bins(&chain, &s, n)[0] as u64
    });

    // --- multi-chain tiling: M=10 chains over one resident tile
    let chains: Vec<ChainParams> =
        (0..10).map(|_| ChainParams::sample(&delta, l, &mut rng)).collect();
    let chain_refs: Vec<&ChainParams> = chains.iter().collect();
    bench("native tile_bins_multi M=10 (per point·chain)", (n * 10) as u64, || {
        NativeBinner.tile_bins_multi(&chain_refs, &s, n)[0] as u64
    });

    // --- CMS insert + query
    let mut cms = CountMinSketch::new(10, 100);
    let bins: Vec<Vec<i32>> = (0..64).map(|i| vec![i as i32; k]).collect();
    bench("CMS insert r=10 w=100 (per insert)", bins.len() as u64, || {
        for b in &bins {
            cms.insert(b);
        }
        cms.total()
    });
    bench("CMS query r=10 w=100 (per query)", bins.len() as u64, || {
        bins.iter().map(|b| cms.query(b) as u64).sum()
    });

    // --- dense projection with memoised R (Gisette shape)
    let d = 512;
    let names: Vec<String> = (0..d).map(|j| format!("f{j}")).collect();
    let proj = Projector::new(k, 1.0 / 3.0).with_dense_schema(&names);
    let rows: Vec<Row> = (0..32)
        .map(|i| Row::dense(i, (0..d).map(|_| rng.normal() as f32).collect()))
        .collect();
    bench("dense project d=512 K=50 (per row)", rows.len() as u64, || {
        rows.iter().map(|r| proj.project(r, None).s[0].abs() as u64).sum()
    });

    // --- sparse projection, memoised hash rows (SpamURL shape)
    let sparse_rows: Vec<Row> = (0..32)
        .map(|i| {
            let mut idx: Vec<u32> =
                (0..120).map(|_| rng.below(100_000) as u32).collect();
            idx.sort();
            idx.dedup();
            let val = vec![1.0f32; idx.len()];
            Row::sparse(i, idx, val)
        })
        .collect();
    let sproj = Projector::new(100, 1.0 / 3.0);
    bench("sparse project nnz≈120 K=100 (per row, memo)", sparse_rows.len() as u64, || {
        let mut memo = std::collections::HashMap::new();
        sparse_rows.iter().map(|r| sproj.project(r, Some(&mut memo)).s[0].abs() as u64).sum()
    });

    // --- sign hash itself
    let h = SignHasher::new(3, 1.0 / 3.0);
    bench("sign hash h_k(name) (per hash)", 64, || {
        (0..64).map(|i| h.feature(&format!("f{i}")) as i64 as u64).sum()
    });

    // --- PJRT artifacts, if built
    match sparx::runtime::PjrtEngine::start_default() {
        Ok(engine) => {
            let gk = 50;
            let gl = 20;
            let gd = 512;
            let gb = 256;
            let delta: Vec<f32> = (0..gk).map(|_| rng.range_f64(0.5, 2.0) as f32).collect();
            let gchain = ChainParams::sample(&delta, gl, &mut rng);
            let gs: Vec<f32> = (0..gb * gk).map(|_| rng.normal() as f32).collect();
            bench("PJRT chain_bins gisette B=256 (per point)", gb as u64, || {
                engine.chain_bins("gisette", &gs, gb, &gchain).unwrap()[0] as u64
            });
            let gx: Vec<f32> = (0..gb * gd).map(|_| rng.normal() as f32).collect();
            let gr: Vec<f32> = (0..gd * gk)
                .map(|_| [(-1.0f32), 0.0, 1.0][rng.below(3) as usize])
                .collect();
            let mut xr = gx.clone();
            xr.extend_from_slice(&gr);
            bench("PJRT project gisette B=256 d=512 (per point)", gb as u64, || {
                engine.project("gisette", &xr, gb).unwrap()[0].abs() as u64
            });
            bench("PJRT fused project_bins gisette (per point)", gb as u64, || {
                engine.project_bins("gisette", &xr, gb, &gchain).unwrap()[0] as u64
            });
        }
        Err(e) => println!("(PJRT benches skipped: {e})"),
    }

    // --- distributed fit+score on a fixed Gisette workload: the fused
    //     single-pass executors vs the legacy one-round-per-chain plan
    //     (BENCH_*.json tracks the gap between these two lines)
    {
        use sparx::cluster::ClusterConfig;
        use sparx::data::generators::GisetteGen;
        use sparx::sparx::{ExecMode, SparxModel, SparxParams};
        let ctx = ClusterConfig {
            num_partitions: 8,
            num_workers: 4,
            num_threads: 4,
            ..Default::default()
        }
        .build();
        let fit_n = 1200;
        let ld = GisetteGen { n: fit_n, d: 128, ..Default::default() }.generate(&ctx).unwrap();
        for mode in ExecMode::ALL {
            let tag = mode.tag();
            let p = SparxParams {
                k: 25,
                num_chains: 25,
                depth: 10,
                sample_rate: 1.0,
                exec_mode: mode,
                ..Default::default()
            };
            bench(&format!("dist fit+score gisette M=25 [{tag}] (per point)"), fit_n as u64, || {
                let model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
                let scores = model.score_dataset(&ctx, &ld.dataset).unwrap();
                scores.len() as u64
            });
        }
    }

    // --- artifact codec: serialize + rehydrate the deployable model
    //     (the save/load stage of the fit → save/load → score lifecycle)
    {
        use sparx::api::{registry, Detector as _, FittedModel as _, SparxBuilder};
        use sparx::cluster::ClusterConfig;
        use sparx::data::generators::GisetteGen;
        let ctx = ClusterConfig { num_partitions: 4, ..Default::default() }.build();
        let ld = GisetteGen { n: 600, d: 64, ..Default::default() }.generate(&ctx).unwrap();
        let det = SparxBuilder::new()
            .k(25)
            .chains(25)
            .depth(10)
            .sample_rate(0.5)
            .build()
            .unwrap();
        let model = det.fit(&ctx, &ld.dataset).unwrap();
        let bytes = model.to_artifact().unwrap().to_bytes();
        println!("(artifact: {} bytes framed, {}B payload)", bytes.len(), model.model_bytes());
        bench("artifact serialize M=25 L=10 (per call)", 1, || {
            model.to_artifact().unwrap().to_bytes().len() as u64
        });
        bench("artifact load_bytes M=25 L=10 (per call)", 1, || {
            // name() as the sink: model_bytes() would re-serialize the
            // payload and double-count the cost being measured
            registry::load_bytes(&bytes).unwrap().name().len() as u64
        });
    }

    // --- streaming update+rescore
    {
        use sparx::cluster::ClusterConfig;
        use sparx::data::generators::GisetteGen;
        use sparx::data::UpdateTriple;
        use sparx::sparx::{SparxModel, SparxParams, StreamScorer};
        let ctx = ClusterConfig { num_partitions: 4, ..Default::default() }.build();
        let ld = GisetteGen { n: 1000, d: 64, ..Default::default() }.generate(&ctx).unwrap();
        let model = SparxModel::fit(
            &ctx,
            &ld.dataset,
            &SparxParams { k: 25, num_chains: 25, depth: 10, ..Default::default() },
        )
        .unwrap();
        let mut scorer = StreamScorer::new(&model, 512).unwrap();
        let mut i = 0u64;
        bench("stream δ-update + rescore M=25 L=10 (per upd)", 16, || {
            let mut acc = 0u64;
            for _ in 0..16 {
                i += 1;
                let s = scorer.update(&UpdateTriple::Num {
                    id: i % 300,
                    feature: "f3".into(),
                    delta: 0.1,
                });
                acc = acc.wrapping_add(s.outlierness.abs() as u64);
            }
            acc
        });
    }

    serve_throughput();
    println!("done");
}

/// Serve-throughput ladder: one fixed synthetic update sequence replayed
/// through the single-threaded scorer (S=1) and the sharded front-end at
/// S = 2, 4, 8 with the same total cache budget. The S=1 line is the
/// baseline the speedup column is relative to; shards share nothing, so
/// scoring work per update is identical at every S (the determinism
/// story lives in tests/sharded.rs) and only the wall clock moves.
fn serve_throughput() {
    use sparx::cluster::ClusterConfig;
    use sparx::data::generators::GisetteGen;
    use sparx::data::{StreamGen, UpdateTriple};
    use sparx::sparx::{ShardedStreamScorer, SparxModel, SparxParams, StreamScorer};

    let ctx = ClusterConfig { num_partitions: 4, ..Default::default() }.build();
    let ld = GisetteGen { n: 1000, d: 64, ..Default::default() }.generate(&ctx).unwrap();
    let model = SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 25, num_chains: 25, depth: 10, ..Default::default() },
    )
    .unwrap();
    let mut gen = StreamGen::new(20_000, ld.dataset.schema.names.clone(), 0xBEEF);
    let updates: Vec<UpdateTriple> = (0..200_000).map(|_| gen.next_update()).collect();

    // resident model footprint: all shards score against ONE Arc-shared
    // ensemble, so the resident bytes are independent of S (the
    // pre-refactor design cloned the chains + CMS blocks per shard,
    // i.e. S×). CI publishes these lines next to the throughput ladder.
    {
        let s1 = StreamScorer::new(&model, 16).unwrap();
        let bytes = s1.resident_ensemble_bytes();
        println!("serve resident ensemble S=1  {bytes:>10} B (1.00x)");
        let s8 = ShardedStreamScorer::new(&model, 8, 16).unwrap();
        let shared = s8.resident_ensemble_bytes();
        println!(
            "serve resident ensemble S=8  {shared:>10} B ({:.2}x — Arc-shared; was {}B at S×)",
            shared as f64 / bytes as f64,
            8 * bytes
        );
        assert_eq!(shared, bytes, "S=8 must hold exactly one resident ensemble");
        let _ = s8.finish();
    }

    let cache_total = 16_384usize;
    let mut base = 0.0f64;
    for shards in [1usize, 2, 4, 8] {
        let per_shard = (cache_total / shards).max(1);
        // sharded arms clone the replay *outside* the timed region:
        // submit() consumes updates, and cloning inside the clock would
        // charge them String allocations the S=1 arm never pays
        let (processed, dt) = if shards == 1 {
            let mut scorer = StreamScorer::new(&model, per_shard).unwrap();
            let t0 = std::time::Instant::now();
            for u in &updates {
                scorer.update(u);
            }
            (scorer.processed(), t0.elapsed().as_secs_f64())
        } else {
            let mut scorer = ShardedStreamScorer::new(&model, shards, per_shard).unwrap();
            let replay = updates.clone();
            let t0 = std::time::Instant::now();
            for u in replay {
                scorer.submit(u);
            }
            (scorer.finish().processed(), t0.elapsed().as_secs_f64())
        };
        assert_eq!(processed, updates.len() as u64, "S={shards}: lost updates");
        let rate = processed as f64 / dt.max(1e-9);
        if shards == 1 {
            base = rate;
        }
        println!(
            "serve throughput S={shards:<2} {rate:>10.0} updates/s  ({:.2}x vs S=1)",
            rate / base.max(1e-9)
        );
    }
}
