//! Micro-benchmarks of the hot paths (harness = false; criterion is not
//! in the offline dependency set, so this uses a small in-file timer with
//! warmup + repetitions + ns/op reporting).
//!
//! Sections — run one with `cargo bench --bench hotpath -- <section>`
//! (any argument that is not a flag or subcommand selects a section; no
//! section argument runs everything):
//!   * `bins`     — chain binning kernels: the reference per-point loop
//!                  vs the floor-cache scalar kernel vs the runtime
//!                  dispatched (AVX2 where available) path, single- and
//!                  multi-chain
//!   * `cms`      — CMS insert/query, pointwise and batched
//!                  (`insert_many` / `query_many`)
//!   * `project`  — hash projection (dense memoised R, sparse rows, the
//!                  sign hash itself)
//!   * `pjrt`     — PJRT tile execution (chain_bins + fused project_bins
//!                  artifacts; skipped when not built)
//!   * `dist`     — distributed fit+score, fused vs per-chain plans
//!   * `artifact` — model artifact serialize / load + framed sizes
//!   * `stream`   — streaming δ-update + rescore, quantized-CMS resident
//!                  sizes
//!   * `ensemble` — heterogeneous-member ensembles: the LPT scheduling
//!                  kernel vs round-robin (assignment cost + predicted
//!                  makespan over a skewed measured-cost profile) and
//!                  the end-to-end six-member fit under both schedules
//!   * `serve`    — sharded serve throughput at S = 1, 2, 4, 8 (CI
//!                  publishes its lines as the step summary)
//!   * `net`      — serve-over-TCP throughput through the real wire
//!                  path: a bound `Server`, loopback clients writing
//!                  update lines and reading replies
//!   * `decay`    — absorb-mode serve throughput with the time-decay
//!                  mechanisms on (half-life halving, window rotation,
//!                  both) vs plain absorb — the boundary-work overhead
//!
//! Modes:
//!   * `--json` additionally writes `BENCH_hotpath.json` (per-kernel
//!     ns/op ladders, sizes, derived speedups) and `BENCH_serve.json`
//!     (throughput ladder) to the working directory. `BENCH_HOST` labels
//!     the host in both files; comparisons only gate between matching
//!     labels.
//!   * `compare <baseline.json> <current.json> [tolerance]` prints a
//!     markdown delta table and exits 1 if any benchmark regressed
//!     beyond the tolerance band (default 0.5 = +50%; microbench noise
//!     on shared runners is real). Files from different hosts are
//!     reported but never gate.
//!   * `table <file.json>` renders a results file as a markdown table
//!     (what CI puts in the step summary).

use sparx::data::Row;
use sparx::hash::{bin_hash, BinHash, SignHasher};
use sparx::sparx::chain::Binner;
use sparx::sparx::{
    kernel_path, tile_bins_reference, tile_bins_scalar, ChainParams, CountMinSketch, NativeBinner,
    Projector,
};
use sparx::util::{Json, Rng};

const SECTIONS: &[&str] = &[
    "bins", "cms", "project", "pjrt", "dist", "artifact", "stream", "ensemble", "serve", "net",
    "decay",
];

/// One timed result, as printed and as written to `BENCH_hotpath.json`.
struct Entry {
    section: String,
    name: String,
    ns_per_item: f64,
    mitems_per_s: f64,
}

/// Collects timings + measured sizes across sections; also owns the
/// section filter so skipped sections pay no setup cost.
struct Recorder {
    filter: Option<String>,
    entries: Vec<Entry>,
    sizes: Vec<(String, u64)>,
}

impl Recorder {
    fn runs(&self, section: &str) -> bool {
        match &self.filter {
            None => true,
            Some(f) => f == section,
        }
    }

    fn bench<F: FnMut() -> u64>(&mut self, section: &str, name: &str, items: u64, mut f: F) {
        if !self.runs(section) {
            return;
        }
        // warmup
        let mut sink = 0u64;
        for _ in 0..3 {
            sink = sink.wrapping_add(f());
        }
        let mut iters = 0u64;
        let t0 = std::time::Instant::now();
        while t0.elapsed().as_secs_f64() < 1.0 {
            sink = sink.wrapping_add(f());
            iters += 1;
        }
        let total = t0.elapsed().as_secs_f64();
        let per_item = total / (iters as f64 * items as f64);
        println!(
            "{name:<52} {:>10.1} ns/item  ({:>8.2} Mitems/s)  [sink {sink}]",
            per_item * 1e9,
            1e-6 / per_item
        );
        self.entries.push(Entry {
            section: section.into(),
            name: name.into(),
            ns_per_item: per_item * 1e9,
            mitems_per_s: 1e-6 / per_item,
        });
    }

    fn size(&mut self, name: &str, bytes: u64) {
        println!("size {name:<47} {bytes:>12} B");
        self.sizes.push((name.into(), bytes));
    }

    fn ns_of(&self, name: &str) -> Option<f64> {
        self.entries.iter().find(|e| e.name == name).map(|e| e.ns_per_item)
    }
}

/// Serve-throughput results, as printed and as `BENCH_serve.json`.
struct ServeData {
    /// (shards, updates/s, speedup vs S=1)
    ladder: Vec<(usize, f64, f64)>,
    resident_ensemble_bytes: u64,
}

/// Serve-over-TCP result (the `net` section of `BENCH_serve.json`).
struct NetData {
    clients: usize,
    shards: usize,
    updates_per_s: f64,
}

/// Decayed-serve results (the `decay` section of `BENCH_serve.json`).
struct DecayData {
    shards: usize,
    /// (arm label, updates/s)
    arms: Vec<(String, f64)>,
}

fn host_label() -> String {
    std::env::var("BENCH_HOST").unwrap_or_else(|_| "unknown".into())
}

fn main() {
    // cargo appends `--bench` to harness = false targets; drop it before
    // dispatching so the first real argument selects the subcommand
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    match args.first().map(String::as_str) {
        Some("compare") => std::process::exit(compare(&args[1..])),
        Some("table") => std::process::exit(table(&args[1..])),
        _ => {}
    }
    let json_mode = args.iter().any(|a| a == "--json");
    let filter = args.iter().find(|a| !a.starts_with("--")).cloned();
    if let Some(f) = &filter {
        if !SECTIONS.contains(&f.as_str()) {
            eprintln!("unknown section {f:?}; known sections: {}", SECTIONS.join(", "));
            std::process::exit(2);
        }
    }
    let mut rec = Recorder { filter, entries: Vec::new(), sizes: Vec::new() };
    println!("== sparx hot-path microbenches (binning kernel: {}) ==", kernel_path());

    run_sections(&mut rec);
    let serve = serve_throughput(&rec);
    let net = net_throughput(&rec);
    let decay = decay_throughput(&rec);

    if json_mode {
        write_hotpath_json(&rec);
        if serve.is_some() || net.is_some() || decay.is_some() {
            write_serve_json(serve.as_ref(), net.as_ref(), decay.as_ref());
        }
    }
    println!("done");
}

fn run_sections(rec: &mut Recorder) {
    let mut rng = Rng::new(7);

    // --- bins: K=50, L=20, tile of 256 — the scoring hot loop. The
    //     reference arm is the oracle loop the kernels are verified
    //     against; reference → scalar → dispatched is the speedup ladder
    if rec.runs("bins") {
        let k = 50;
        let l = 20;
        let n = 256;
        let delta: Vec<f32> = (0..k).map(|_| rng.range_f64(0.5, 2.0) as f32).collect();
        let chain = ChainParams::sample(&delta, l, &mut rng);
        let s: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        rec.bench("bins", "tile_bins reference K=50 L=20 (per point)", n as u64, || {
            tile_bins_reference(&chain, &s, n)[0] as u64
        });
        rec.bench("bins", "tile_bins scalar K=50 L=20 (per point)", n as u64, || {
            tile_bins_scalar(&chain, &s, n)[0] as u64
        });
        rec.bench("bins", "tile_bins dispatched K=50 L=20 (per point)", n as u64, || {
            NativeBinner.tile_bins(&chain, &s, n).unwrap()[0] as u64
        });

        // multi-chain tiling: M=10 chains over one resident tile
        let chains: Vec<ChainParams> =
            (0..10).map(|_| ChainParams::sample(&delta, l, &mut rng)).collect();
        let refs: Vec<&ChainParams> = chains.iter().collect();
        let items = (n * 10) as u64;
        rec.bench("bins", "tile_bins_multi reference M=10 (per point·chain)", items, || {
            let mut acc = 0u64;
            for c in &chains {
                acc = acc.wrapping_add(tile_bins_reference(c, &s, n)[0] as u64);
            }
            acc
        });
        rec.bench("bins", "tile_bins_multi dispatched M=10 (per point·chain)", items, || {
            NativeBinner.tile_bins_multi(&refs, &s, n).unwrap()[0] as u64
        });
    }

    // --- cms: pointwise and batched entry points
    if rec.runs("cms") {
        let k = 50;
        let mut cms = CountMinSketch::new(10, 100);
        let bins: Vec<Vec<i32>> = (0..64).map(|i| vec![i as i32; k]).collect();
        rec.bench("cms", "CMS insert r=10 w=100 (per insert)", bins.len() as u64, || {
            for b in &bins {
                cms.insert(b);
            }
            cms.total()
        });
        rec.bench("cms", "CMS query r=10 w=100 (per query)", bins.len() as u64, || {
            bins.iter().map(|b| cms.query(b) as u64).sum()
        });
        let hashes: Vec<BinHash> = bins.iter().map(|b| bin_hash(b)).collect();
        rec.bench("cms", "CMS insert_many r=10 w=100 (per insert)", hashes.len() as u64, || {
            cms.insert_many(&hashes);
            cms.total()
        });
        let mut out = vec![0u32; hashes.len()];
        rec.bench("cms", "CMS query_many r=10 w=100 (per query)", hashes.len() as u64, || {
            cms.query_many(&hashes, &mut out);
            out.iter().map(|&x| x as u64).sum()
        });
    }

    // --- project: dense memoised R (Gisette shape), sparse on-the-fly
    //     (SpamURL shape), and the sign hash itself
    if rec.runs("project") {
        let k = 50;
        let d = 512;
        let names: Vec<String> = (0..d).map(|j| format!("f{j}")).collect();
        let proj = Projector::new(k, 1.0 / 3.0).with_dense_schema(&names);
        let rows: Vec<Row> = (0..32)
            .map(|i| Row::dense(i, (0..d).map(|_| rng.normal() as f32).collect()))
            .collect();
        rec.bench("project", "dense project d=512 K=50 (per row)", rows.len() as u64, || {
            rows.iter().map(|r| proj.project(r, None).s[0].abs() as u64).sum()
        });

        let sparse_rows: Vec<Row> = (0..32)
            .map(|i| {
                let mut idx: Vec<u32> =
                    (0..120).map(|_| rng.below(100_000) as u32).collect();
                idx.sort();
                idx.dedup();
                let val = vec![1.0f32; idx.len()];
                Row::sparse(i, idx, val)
            })
            .collect();
        let sproj = Projector::new(100, 1.0 / 3.0);
        let items = sparse_rows.len() as u64;
        rec.bench("project", "sparse project nnz≈120 K=100 (per row, memo)", items, || {
            let mut memo = std::collections::HashMap::new();
            sparse_rows.iter().map(|r| sproj.project(r, Some(&mut memo)).s[0].abs() as u64).sum()
        });

        let h = SignHasher::new(3, 1.0 / 3.0);
        rec.bench("project", "sign hash h_k(name) (per hash)", 64, || {
            (0..64).map(|i| h.feature(&format!("f{i}")) as i64 as u64).sum()
        });
    }

    // --- pjrt: AOT Pallas artifacts, if built
    if rec.runs("pjrt") {
        match sparx::runtime::PjrtEngine::start_default() {
            Ok(engine) => {
                let gk = 50;
                let gl = 20;
                let gd = 512;
                let gb = 256;
                let delta: Vec<f32> = (0..gk).map(|_| rng.range_f64(0.5, 2.0) as f32).collect();
                let gchain = ChainParams::sample(&delta, gl, &mut rng);
                let gs: Vec<f32> = (0..gb * gk).map(|_| rng.normal() as f32).collect();
                rec.bench("pjrt", "PJRT chain_bins gisette B=256 (per point)", gb as u64, || {
                    engine.chain_bins("gisette", &gs, gb, &gchain).unwrap()[0] as u64
                });
                let gx: Vec<f32> = (0..gb * gd).map(|_| rng.normal() as f32).collect();
                let gr: Vec<f32> = (0..gd * gk)
                    .map(|_| [(-1.0f32), 0.0, 1.0][rng.below(3) as usize])
                    .collect();
                let mut xr = gx.clone();
                xr.extend_from_slice(&gr);
                rec.bench("pjrt", "PJRT project gisette B=256 d=512 (per point)", gb as u64, || {
                    engine.project("gisette", &xr, gb).unwrap()[0].abs() as u64
                });
                rec.bench("pjrt", "PJRT fused project_bins gisette (per point)", gb as u64, || {
                    engine.project_bins("gisette", &xr, gb, &gchain).unwrap()[0] as u64
                });
            }
            Err(e) => println!("(PJRT benches skipped: {e})"),
        }
    }

    // --- dist: fit+score on a fixed Gisette workload, the fused
    //     single-pass executors vs the legacy one-round-per-chain plan
    //     (BENCH_hotpath.json tracks the gap between these two lines)
    if rec.runs("dist") {
        use sparx::cluster::ClusterConfig;
        use sparx::data::generators::GisetteGen;
        use sparx::sparx::{ExecMode, SparxModel, SparxParams};
        let ctx = ClusterConfig {
            num_partitions: 8,
            num_workers: 4,
            num_threads: 4,
            ..Default::default()
        }
        .build();
        let fit_n = 1200;
        let ld = GisetteGen { n: fit_n, d: 128, ..Default::default() }.generate(&ctx).unwrap();
        for mode in ExecMode::ALL {
            let tag = mode.tag();
            let p = SparxParams {
                k: 25,
                num_chains: 25,
                depth: 10,
                sample_rate: 1.0,
                exec_mode: mode,
                ..Default::default()
            };
            let name = format!("dist fit+score gisette M=25 [{tag}] (per point)");
            rec.bench("dist", &name, fit_n as u64, || {
                let model = SparxModel::fit(&ctx, &ld.dataset, &p).unwrap();
                let scores = model.score_dataset(&ctx, &ld.dataset).unwrap();
                scores.len() as u64
            });
        }
    }

    // --- artifact codec: serialize + rehydrate the deployable model
    //     (the save/load stage of the fit → save/load → score lifecycle)
    if rec.runs("artifact") {
        use sparx::api::{registry, Detector as _, FittedModel as _, SparxBuilder};
        use sparx::cluster::ClusterConfig;
        use sparx::data::generators::GisetteGen;
        let ctx = ClusterConfig { num_partitions: 4, ..Default::default() }.build();
        let ld = GisetteGen { n: 600, d: 64, ..Default::default() }.generate(&ctx).unwrap();
        let det = SparxBuilder::new()
            .k(25)
            .chains(25)
            .depth(10)
            .sample_rate(0.5)
            .build()
            .unwrap();
        let model = det.fit(&ctx, &ld.dataset).unwrap();
        let bytes = model.to_artifact().unwrap().to_bytes();
        rec.size("artifact framed (v3, packed counts)", bytes.len() as u64);
        rec.size("artifact payload", model.model_bytes() as u64);
        rec.bench("artifact", "artifact serialize M=25 L=10 (per call)", 1, || {
            model.to_artifact().unwrap().to_bytes().len() as u64
        });
        rec.bench("artifact", "artifact load_bytes M=25 L=10 (per call)", 1, || {
            // name() as the sink: model_bytes() would re-serialize the
            // payload and double-count the cost being measured
            registry::load_bytes(&bytes).unwrap().name().len() as u64
        });
    }

    // --- stream: δ-update + rescore, plus the residency the quantized
    //     CMS counters actually occupy vs the pre-quantization u32 layout
    if rec.runs("stream") {
        use sparx::cluster::ClusterConfig;
        use sparx::data::generators::GisetteGen;
        use sparx::data::UpdateTriple;
        use sparx::sparx::{SparxModel, SparxParams, StreamScorer};
        let ctx = ClusterConfig { num_partitions: 4, ..Default::default() }.build();
        let ld = GisetteGen { n: 1000, d: 64, ..Default::default() }.generate(&ctx).unwrap();
        let model = SparxModel::fit(
            &ctx,
            &ld.dataset,
            &SparxParams { k: 25, num_chains: 25, depth: 10, ..Default::default() },
        )
        .unwrap();
        let (mut quantized, mut u32_layout) = (0u64, 0u64);
        for chain in &model.chains {
            for cms in &chain.cms {
                let cells = (cms.rows() * cms.cols()) as u64;
                quantized += cells * cms.storage_bits() as u64 / 8;
                u32_layout += cells * 4;
            }
        }
        rec.size("CMS counters resident (quantized)", quantized);
        rec.size("CMS counters resident (u32 layout)", u32_layout);
        let mut scorer = StreamScorer::new(&model, 512).unwrap();
        let mut i = 0u64;
        rec.bench("stream", "stream δ-update + rescore M=25 L=10 (per upd)", 16, || {
            let mut acc = 0u64;
            for _ in 0..16 {
                i += 1;
                let s = scorer.update(&UpdateTriple::Num {
                    id: i % 300,
                    feature: "f3".into(),
                    delta: 0.1,
                });
                acc = acc.wrapping_add(s.outlierness.abs() as u64);
            }
            acc
        });
    }

    // --- ensemble: heterogeneous members behind one spec string. Two
    //     timed kernels (the LPT packer vs the naive baseline over a
    //     skewed cost profile), the makespan each schedule predicts
    //     (printed + asserted: LPT never loses), then the end-to-end
    //     six-member fit under both schedules — same members, same
    //     seeds, only worker placement moves, so the wall-clock gap is
    //     the scheduling win (scores are bit-identical under either
    //     schedule; tests/ensemble.rs holds that contract)
    if rec.runs("ensemble") {
        use sparx::api::{registry, Detector as _, FittedModel as _};
        use sparx::cluster::ClusterConfig;
        use sparx::data::generators::GisetteGen;
        use sparx::ensemble::cost::{assign_balanced, assign_round_robin, makespan};

        // skewed measured-cost profile (µs): a few dominant members over
        // a cheap tail — the shape real four-kind ensembles produce
        let costs: Vec<u64> = (0..64)
            .map(|i| if i % 16 == 0 { 9_000 } else { 80 + (i as u64 % 7) * 20 })
            .collect();
        let workers = 4usize;
        rec.bench("ensemble", "schedule assign_balanced n=64 W=4 (per member)", 64, || {
            assign_balanced(&costs, workers).iter().map(|&w| w as u64).sum()
        });
        rec.bench("ensemble", "schedule assign_round_robin n=64 W=4 (per member)", 64, || {
            assign_round_robin(costs.len(), workers).iter().map(|&w| w as u64).sum()
        });
        let balanced = makespan(&costs, &assign_balanced(&costs, workers), workers);
        let naive = makespan(&costs, &assign_round_robin(costs.len(), workers), workers);
        assert!(balanced <= naive, "LPT must never lose to round-robin");
        println!(
            "ensemble makespan W={workers}  balanced {balanced} µs  \
             round-robin {naive} µs  ({:.2}x better)",
            naive as f64 / balanced.max(1) as f64
        );

        // six members over two pool workers, so the schedules genuinely
        // diverge (with members ≤ workers both place one per worker and
        // the gap would be zero by construction): round-robin stacks the
        // dominant sparx with two mid-cost members on worker 0, LPT
        // gives it a worker to itself
        let ctx =
            ClusterConfig { num_partitions: 4, num_workers: 2, ..Default::default() }.build();
        let fit_n = 600;
        let ld = GisetteGen { n: fit_n, d: 64, ..Default::default() }.generate(&ctx).unwrap();
        for sched in ["balanced", "round-robin"] {
            let spec = format!(
                "ensemble?members=sparx:k=25:chains=25:depth=10,xstream:k=10:depth=8,\
                 spif:trees=12:depth=8,dbscout:min-pts=4,xstream:k=8:depth=6,\
                 spif:trees=8:depth=6&seed=7&schedule={sched}"
            );
            let det = registry::create(&spec).unwrap();
            let name = format!("ensemble fit 6 members W=2 [{sched}] (per point)");
            rec.bench("ensemble", &name, fit_n as u64, || {
                let model = det.fit(&ctx, &ld.dataset).unwrap();
                model.score(&ctx, &ld.dataset).unwrap().len() as u64
            });
        }
    }
}

/// Serve-throughput ladder: one fixed synthetic update sequence replayed
/// through the single-threaded scorer (S=1) and the sharded front-end at
/// S = 2, 4, 8 with the same total cache budget. The S=1 line is the
/// baseline the speedup column is relative to; shards share nothing, so
/// scoring work per update is identical at every S (the determinism
/// story lives in tests/sharded.rs) and only the wall clock moves.
fn serve_throughput(rec: &Recorder) -> Option<ServeData> {
    if !rec.runs("serve") {
        return None;
    }
    use sparx::cluster::ClusterConfig;
    use sparx::data::generators::GisetteGen;
    use sparx::data::{StreamGen, UpdateTriple};
    use sparx::sparx::{ShardedStreamScorer, SparxModel, SparxParams, StreamScorer};

    let ctx = ClusterConfig { num_partitions: 4, ..Default::default() }.build();
    let ld = GisetteGen { n: 1000, d: 64, ..Default::default() }.generate(&ctx).unwrap();
    let model = SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 25, num_chains: 25, depth: 10, ..Default::default() },
    )
    .unwrap();
    let mut gen = StreamGen::new(20_000, ld.dataset.schema.names.clone(), 0xBEEF);
    let updates: Vec<UpdateTriple> = (0..200_000).map(|_| gen.next_update()).collect();

    // resident model footprint: all shards score against ONE Arc-shared
    // ensemble, so the resident bytes are independent of S (the
    // pre-refactor design cloned the chains + CMS blocks per shard,
    // i.e. S×). CI publishes these lines next to the throughput ladder.
    let resident = {
        let s1 = StreamScorer::new(&model, 16).unwrap();
        let bytes = s1.resident_ensemble_bytes();
        println!("serve resident ensemble S=1  {bytes:>10} B (1.00x)");
        let s8 = ShardedStreamScorer::new(&model, 8, 16).unwrap();
        let shared = s8.resident_ensemble_bytes();
        println!(
            "serve resident ensemble S=8  {shared:>10} B ({:.2}x — Arc-shared; was {}B at S×)",
            shared as f64 / bytes as f64,
            8 * bytes
        );
        assert_eq!(shared, bytes, "S=8 must hold exactly one resident ensemble");
        let _ = s8.finish();
        bytes as u64
    };

    // the cache budget is GLOBAL since the feeder-directory refactor:
    // every arm holds the same total, so eviction decisions — and the
    // scores — are bit-identical at every S; only the wall clock moves
    let cache_total = 16_384usize;
    let mut base = 0.0f64;
    let mut ladder = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        // sharded arms clone the replay *outside* the timed region:
        // submit() consumes updates, and cloning inside the clock would
        // charge them String allocations the S=1 arm never pays
        let (processed, dt) = if shards == 1 {
            let mut scorer = StreamScorer::new(&model, cache_total).unwrap();
            let t0 = std::time::Instant::now();
            for u in &updates {
                scorer.update(u);
            }
            (scorer.processed(), t0.elapsed().as_secs_f64())
        } else {
            let mut scorer = ShardedStreamScorer::new(&model, shards, cache_total).unwrap();
            let replay = updates.clone();
            let t0 = std::time::Instant::now();
            for u in replay {
                scorer.submit(u);
            }
            (scorer.finish().processed(), t0.elapsed().as_secs_f64())
        };
        assert_eq!(processed, updates.len() as u64, "S={shards}: lost updates");
        let rate = processed as f64 / dt.max(1e-9);
        if shards == 1 {
            base = rate;
        }
        let speedup = rate / base.max(1e-9);
        println!("serve throughput S={shards:<2} {rate:>10.0} updates/s  ({speedup:.2}x vs S=1)");
        ladder.push((shards, rate, speedup));
    }
    Some(ServeData { ladder, resident_ensemble_bytes: resident })
}

/// `net` section: the serve path again, but through the real TCP
/// ingress — a bound `Server`, loopback clients writing update lines
/// and reading replies concurrently. The gap between this line and the
/// in-process `serve` ladder is the wire + framing overhead; both land
/// in `BENCH_serve.json`.
fn net_throughput(rec: &Recorder) -> Option<NetData> {
    if !rec.runs("net") {
        return None;
    }
    use sparx::cluster::ClusterConfig;
    use sparx::data::generators::GisetteGen;
    use sparx::data::StreamGen;
    use sparx::serve::{Engine, Server};
    use sparx::sparx::{ShardedStreamScorer, SparxModel, SparxParams};
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let ctx = ClusterConfig { num_partitions: 4, ..Default::default() }.build();
    let ld = GisetteGen { n: 1000, d: 64, ..Default::default() }.generate(&ctx).unwrap();
    let model = SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 25, num_chains: 25, depth: 10, ..Default::default() },
    )
    .unwrap();
    let (clients, shards, per_client) = (4usize, 4usize, 25_000usize);
    let scorer = ShardedStreamScorer::new(&model, shards, 16_384).unwrap();
    let server = Server::bind("127.0.0.1:0", Engine::new(scorer, "bench.sparx", None)).unwrap();
    let addr = server.local_addr();
    let server = std::thread::spawn(move || server.run());

    let mut gen = StreamGen::new(20_000, ld.dataset.schema.names.clone(), 0xBEEF);
    let batches: Vec<String> = (0..clients)
        .map(|_| {
            let mut text = String::new();
            for _ in 0..per_client {
                text.push_str(
                    &gen.next_update().to_line().expect("generator updates always render"),
                );
                text.push('\n');
            }
            text
        })
        .collect();

    let t0 = std::time::Instant::now();
    let workers: Vec<_> = batches
        .into_iter()
        .map(|payload| {
            std::thread::spawn(move || -> u64 {
                let sock = TcpStream::connect(addr).expect("connect to the bench server");
                let mut wr = sock.try_clone().expect("clone the client socket");
                // write from a side thread while this thread reads, so a
                // full pending window never wedges the client
                let push = std::thread::spawn(move || {
                    wr.write_all(payload.as_bytes()).expect("write updates");
                    wr.write_all(b"QUIT\n").expect("write QUIT");
                });
                // read to EOF: the server half-closes after draining, and
                // queued score replies may legitimately land after OK bye
                let mut replies = 0u64;
                for line in BufReader::new(sock).lines() {
                    let Ok(line) = line else { break };
                    if (line.starts_with("OK ") && line != "OK bye") || line.starts_with("BUSY ") {
                        replies += 1;
                    }
                }
                push.join().expect("client writer half");
                replies
            })
        })
        .collect();
    let replies: u64 = workers.into_iter().map(|w| w.join().expect("client thread")).sum();
    let dt = t0.elapsed().as_secs_f64();

    {
        let mut ctl = TcpStream::connect(addr).expect("connect for SHUTDOWN");
        ctl.write_all(b"SHUTDOWN\n").expect("write SHUTDOWN");
        let mut line = String::new();
        let _ = BufReader::new(ctl).read_line(&mut line);
    }
    let scorer = server.join().expect("server thread").expect("server run");
    let report = scorer.finish();
    assert_eq!(
        replies,
        (clients * per_client) as u64,
        "every request line must be answered (OK or BUSY)"
    );
    let rate = report.processed() as f64 / dt.max(1e-9);
    println!(
        "serve-over-TCP  C={clients} S={shards} {rate:>10.0} updates/s  ({} accepted of {} sent)",
        report.processed(),
        clients * per_client
    );
    Some(NetData { clients, shards, updates_per_s: rate })
}

/// `decay` section: absorb-mode serve throughput with the logical-clock
/// decay mechanisms on — the cost of half-life floor-halving and window
/// rotation boundaries (feeder masters + per-shard broadcasts) relative
/// to plain absorb over the same replay. Lands in `BENCH_serve.json`.
fn decay_throughput(rec: &Recorder) -> Option<DecayData> {
    if !rec.runs("decay") {
        return None;
    }
    use sparx::cluster::ClusterConfig;
    use sparx::data::generators::GisetteGen;
    use sparx::data::{StreamGen, UpdateTriple};
    use sparx::sparx::{
        DecaySpec, ServeOptions, ServedEnsemble, ShardedStreamScorer, SparxModel, SparxParams,
    };
    use std::sync::Arc;

    let ctx = ClusterConfig { num_partitions: 4, ..Default::default() }.build();
    let ld = GisetteGen { n: 1000, d: 64, ..Default::default() }.generate(&ctx).unwrap();
    let model = SparxModel::fit(
        &ctx,
        &ld.dataset,
        &SparxParams { k: 25, num_chains: 25, depth: 10, ..Default::default() },
    )
    .unwrap();
    let mut gen = StreamGen::new(20_000, ld.dataset.schema.names.clone(), 0xBEEF);
    let updates: Vec<UpdateTriple> = (0..100_000).map(|_| gen.next_update()).collect();
    let (shards, cache_total) = (4usize, 16_384usize);
    // 4096 puts dozens of boundaries inside the replay without making
    // boundary work dominate — the realistic serving regime
    let arms: [(&str, DecaySpec); 4] = [
        ("absorb (no decay)", DecaySpec::default()),
        ("half-life 4096", DecaySpec::new(4096, 0)),
        ("window 4096", DecaySpec::new(0, 4096)),
        ("half-life + window 4096", DecaySpec::new(4096, 4096)),
    ];
    let mut results = Vec::new();
    for (label, decay) in arms {
        let opts = ServeOptions { record: false, absorb: true, decay, ..Default::default() };
        let ensemble = Arc::new(ServedEnsemble::new(&model).unwrap());
        let mut scorer =
            ShardedStreamScorer::from_ensemble(
        ensemble,
        opts.shards(shards).cache(cache_total),
        None,
    )
                .unwrap();
        let replay = updates.clone();
        let t0 = std::time::Instant::now();
        for u in replay {
            scorer.submit(u);
        }
        let processed = scorer.finish().processed();
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(processed, updates.len() as u64, "decay arm {label:?}: lost updates");
        let rate = processed as f64 / dt.max(1e-9);
        println!("serve decay S={shards} {label:<28} {rate:>10.0} updates/s");
        results.push((label.to_string(), rate));
    }
    Some(DecayData { shards, arms: results })
}

// ------------------------------------------------------------- json I/O

fn write_hotpath_json(rec: &Recorder) {
    let entries: Vec<Json> = rec
        .entries
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("section", Json::Str(e.section.clone())),
                ("name", Json::Str(e.name.clone())),
                ("ns_per_item", Json::Num(e.ns_per_item)),
                ("mitems_per_s", Json::Num(e.mitems_per_s)),
            ])
        })
        .collect();
    let sizes: Vec<(&str, Json)> =
        rec.sizes.iter().map(|(n, b)| (n.as_str(), Json::Num(*b as f64))).collect();
    let mut derived: Vec<(&str, Json)> = Vec::new();
    let speedup = |a: Option<f64>, b: Option<f64>| match (a, b) {
        (Some(r), Some(d)) if d > 0.0 => Some(r / d),
        _ => None,
    };
    if let Some(s) = speedup(
        rec.ns_of("tile_bins reference K=50 L=20 (per point)"),
        rec.ns_of("tile_bins dispatched K=50 L=20 (per point)"),
    ) {
        derived.push(("tile_bins_speedup_vs_reference", Json::Num(s)));
    }
    if let Some(s) = speedup(
        rec.ns_of("tile_bins_multi reference M=10 (per point·chain)"),
        rec.ns_of("tile_bins_multi dispatched M=10 (per point·chain)"),
    ) {
        derived.push(("tile_bins_multi_speedup_vs_reference", Json::Num(s)));
    }
    if let Some(s) = speedup(
        rec.ns_of("ensemble fit 6 members W=2 [round-robin] (per point)"),
        rec.ns_of("ensemble fit 6 members W=2 [balanced] (per point)"),
    ) {
        derived.push(("ensemble_balanced_fit_speedup_vs_round_robin", Json::Num(s)));
    }
    let doc = Json::obj(vec![
        ("schema", Json::Str("sparx-bench-hotpath/1".into())),
        ("host", Json::Str(host_label())),
        ("kernel", Json::Str(kernel_path().into())),
        ("entries", Json::Arr(entries)),
        ("sizes", Json::obj(sizes)),
        ("derived", Json::obj(derived)),
    ]);
    std::fs::write("BENCH_hotpath.json", format!("{doc}\n")).expect("write BENCH_hotpath.json");
    println!("(wrote BENCH_hotpath.json)");
}

fn write_serve_json(serve: Option<&ServeData>, net: Option<&NetData>, decay: Option<&DecayData>) {
    let ladder: Vec<Json> = serve
        .map(|s| {
            s.ladder
                .iter()
                .map(|&(shards, rate, speedup)| {
                    Json::obj(vec![
                        ("shards", Json::Num(shards as f64)),
                        ("updates_per_s", Json::Num(rate)),
                        ("speedup_vs_s1", Json::Num(speedup)),
                    ])
                })
                .collect()
        })
        .unwrap_or_default();
    let mut fields = vec![
        ("schema", Json::Str("sparx-bench-serve/1".into())),
        ("host", Json::Str(host_label())),
        ("kernel", Json::Str(kernel_path().into())),
        ("ladder", Json::Arr(ladder)),
    ];
    if let Some(s) = serve {
        fields.push(("resident_ensemble_bytes", Json::Num(s.resident_ensemble_bytes as f64)));
    }
    if let Some(n) = net {
        fields.push((
            "net",
            Json::obj(vec![
                ("clients", Json::Num(n.clients as f64)),
                ("shards", Json::Num(n.shards as f64)),
                ("updates_per_s", Json::Num(n.updates_per_s)),
            ]),
        ));
    }
    if let Some(d) = decay {
        let arms: Vec<Json> = d
            .arms
            .iter()
            .map(|(label, rate)| {
                Json::obj(vec![
                    ("name", Json::Str(label.clone())),
                    ("updates_per_s", Json::Num(*rate)),
                ])
            })
            .collect();
        fields.push((
            "decay",
            Json::obj(vec![("shards", Json::Num(d.shards as f64)), ("arms", Json::Arr(arms))]),
        ));
    }
    let doc = Json::obj(fields);
    std::fs::write("BENCH_serve.json", format!("{doc}\n")).expect("write BENCH_serve.json");
    println!("(wrote BENCH_serve.json)");
}

fn read_json(path: &str) -> Result<Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("{path}: {e}"))
}

/// `compare <baseline.json> <current.json> [tolerance]` — markdown delta
/// table on stdout; exit 1 on regression, 0 otherwise, 2 on usage/parse
/// errors. Host labels must match for the gate to arm: a baseline from
/// different hardware is context, not a contract.
fn compare(args: &[String]) -> i32 {
    let (Some(base_path), Some(cur_path)) = (args.first(), args.get(1)) else {
        eprintln!("usage: hotpath compare <baseline.json> <current.json> [tolerance]");
        return 2;
    };
    let tol: f64 = match args.get(2) {
        Some(t) => match t.parse() {
            Ok(v) => v,
            Err(_) => {
                eprintln!("bad tolerance {t:?}");
                return 2;
            }
        },
        None => 0.5,
    };
    let (base, cur) = match (read_json(base_path), read_json(cur_path)) {
        (Ok(b), Ok(c)) => (b, c),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("compare: {e}");
            return 2;
        }
    };
    let base_host = base.get("host").and_then(Json::as_str).unwrap_or("unknown");
    let cur_host = cur.get("host").and_then(Json::as_str).unwrap_or("unknown");
    let gate = base_host == cur_host;
    let lookup = |doc: &Json, name: &str| -> Option<f64> {
        doc.get("entries")?
            .items()
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
            .and_then(|e| e.get("ns_per_item"))
            .and_then(Json::as_f64)
    };
    println!("| benchmark | baseline ns/item | current ns/item | Δ |");
    println!("|---|---:|---:|---:|");
    let mut regressions = 0usize;
    for e in cur.get("entries").map(Json::items).unwrap_or(&[]) {
        let Some(name) = e.get("name").and_then(Json::as_str) else { continue };
        let Some(ns) = e.get("ns_per_item").and_then(Json::as_f64) else { continue };
        match lookup(&base, name) {
            Some(b) if b > 0.0 => {
                let delta = ns / b - 1.0;
                let flag = if delta > tol {
                    regressions += 1;
                    " ⚠ regression"
                } else {
                    ""
                };
                println!("| {name} | {b:.1} | {ns:.1} | {:+.1}%{flag} |", delta * 100.0);
            }
            _ => println!("| {name} | — | {ns:.1} | new |"),
        }
    }
    if !gate {
        println!();
        println!(
            "_hosts differ (baseline {base_host:?}, current {cur_host:?}) — \
             informational only, not gating_"
        );
        return 0;
    }
    if regressions > 0 {
        eprintln!(
            "{regressions} benchmark(s) regressed beyond the {:.0}% tolerance band",
            tol * 100.0
        );
        return 1;
    }
    0
}

/// `table <file.json>` — render a results file as a markdown table.
fn table(args: &[String]) -> i32 {
    let Some(path) = args.first() else {
        eprintln!("usage: hotpath table <file.json>");
        return 2;
    };
    let doc = match read_json(path) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("table: {e}");
            return 2;
        }
    };
    let host = doc.get("host").and_then(Json::as_str).unwrap_or("unknown");
    let kernel = doc.get("kernel").and_then(Json::as_str).unwrap_or("?");
    if let Some(ladder) = doc.get("ladder") {
        println!("**serve throughput** (host {host}, kernel {kernel})");
        println!();
        println!("| shards | updates/s | speedup vs S=1 |");
        println!("|---:|---:|---:|");
        for e in ladder.items() {
            let s = e.get("shards").and_then(Json::as_usize).unwrap_or(0);
            let r = e.get("updates_per_s").and_then(Json::as_f64).unwrap_or(0.0);
            let x = e.get("speedup_vs_s1").and_then(Json::as_f64).unwrap_or(0.0);
            println!("| {s} | {r:.0} | {x:.2}x |");
        }
        if let Some(net) = doc.get("net") {
            let c = net.get("clients").and_then(Json::as_usize).unwrap_or(0);
            let s = net.get("shards").and_then(Json::as_usize).unwrap_or(0);
            let r = net.get("updates_per_s").and_then(Json::as_f64).unwrap_or(0.0);
            println!();
            println!("serve-over-TCP: {r:.0} updates/s ({c} clients, S={s})");
        }
        if let Some(decay) = doc.get("decay") {
            let s = decay.get("shards").and_then(Json::as_usize).unwrap_or(0);
            println!();
            println!("**decayed serve** (S={s})");
            println!();
            println!("| arm | updates/s |");
            println!("|---|---:|");
            for e in decay.get("arms").map(Json::items).unwrap_or(&[]) {
                let name = e.get("name").and_then(Json::as_str).unwrap_or("");
                let r = e.get("updates_per_s").and_then(Json::as_f64).unwrap_or(0.0);
                println!("| {name} | {r:.0} |");
            }
        }
        return 0;
    }
    println!("**hot-path kernels** (host {host}, kernel {kernel})");
    println!();
    println!("| section | benchmark | ns/item | Mitems/s |");
    println!("|---|---|---:|---:|");
    for e in doc.get("entries").map(Json::items).unwrap_or(&[]) {
        let sec = e.get("section").and_then(Json::as_str).unwrap_or("");
        let name = e.get("name").and_then(Json::as_str).unwrap_or("");
        let ns = e.get("ns_per_item").and_then(Json::as_f64).unwrap_or(0.0);
        let mi = e.get("mitems_per_s").and_then(Json::as_f64).unwrap_or(0.0);
        println!("| {sec} | {name} | {ns:.1} | {mi:.2} |");
    }
    if let Some(Json::Obj(sizes)) = doc.get("sizes") {
        println!();
        println!("| size | bytes |");
        println!("|---|---:|");
        for (name, v) in sizes {
            println!("| {name} | {:.0} |", v.as_f64().unwrap_or(0.0));
        }
    }
    if let Some(Json::Obj(derived)) = doc.get("derived") {
        println!();
        for (name, v) in derived {
            println!("- **{name}**: {:.2}x", v.as_f64().unwrap_or(0.0));
        }
    }
    0
}
