//! Bench: regenerates the paper's table3 (see DESIGN.md experiment index).
//! Runs the experiment at bench scale (override with SPARX_SCALE) and
//! prints the result table; harness = false (criterion unavailable in the
//! offline dependency set — see Cargo.toml).

fn main() {
    let scale = sparx::experiments::scale::from_env(0.12);
    let t0 = std::time::Instant::now();
    let results = sparx::experiments::run("table3", scale, None).unwrap_or_else(|e| {
        eprintln!("table3: {e}");
        std::process::exit(e.exit_code());
    });
    for result in results {
        println!("{}", result.to_markdown());
        let failed: Vec<&str> = result
            .checks
            .iter()
            .filter(|(_, ok)| !ok)
            .map(|(what, _)| what.as_str())
            .collect();
        if !failed.is_empty() {
            println!("WARNING: shape checks failed: {failed:?}");
        }
    }
    println!(
        "bench table3_head_to_head: total {:.1}s at scale {scale}",
        t0.elapsed().as_secs_f64()
    );
}
