"""AOT pipeline tests: manifest consistency, HLO lowering sanity, and the
L2 model compositions at every artifact variant's exact shapes."""

from __future__ import annotations

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels.ref import chain_bins_ref, project_ref

RNG = np.random.default_rng(7)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def variant_args(v, kind):
    b, d, k, l = v["b"], v["d"], v["k"], v["l"]
    x = RNG.standard_normal((b, d)).astype(np.float32)
    r = RNG.choice([-1.0, 0.0, 1.0], size=(d, k)).astype(np.float32)
    s = RNG.standard_normal((b, k)).astype(np.float32)
    delta = RNG.uniform(0.5, 2.0, size=k).astype(np.float32)
    shift = (RNG.uniform(0, 1, size=k) * delta).astype(np.float32)
    fs = RNG.integers(0, k, size=l).astype(np.int32)
    if kind == "project":
        return (x, r)
    if kind == "chain_bins":
        return (s, delta, shift, fs)
    return (x, r, delta, shift, fs)


@pytest.mark.parametrize("name", list(aot.VARIANTS))
def test_model_runs_at_variant_shapes(name):
    v = aot.VARIANTS[name]
    for kind in aot.KINDS[name]:
        fn, _specs = aot.specs(v, kind)
        out = fn(*[jnp.asarray(a) for a in variant_args(v, kind)])
        assert isinstance(out, tuple) and len(out) == 1
        if kind == "project":
            assert out[0].shape == (v["b"], v["k"])
        else:
            assert out[0].shape == (v["b"], v["l"], v["k"])
            assert out[0].dtype == jnp.int32


def test_lowering_produces_parsable_hlo_text():
    v = aot.VARIANTS["demo"]
    fn, args = aot.specs(v, "chain_bins")
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # must be pure HLO ops — interpret=True means no Mosaic custom-calls
    assert "custom-call" not in text or "Sharding" in text


def test_manifest_matches_variants_when_built():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        manifest = json.load(f)
    seen = {(e["name"], e["kind"]) for e in manifest["artifacts"]}
    for name, kinds in aot.KINDS.items():
        for kind in kinds:
            assert (name, kind) in seen, f"missing artifact {kind}_{name}"
    for e in manifest["artifacts"]:
        v = aot.VARIANTS[e["name"]]
        assert (e["b"], e["d"], e["k"], e["l"]) == (v["b"], v["d"], v["k"], v["l"])
        assert os.path.exists(os.path.join(ART_DIR, e["file"]))


def test_model_composition_matches_oracle_end_to_end():
    """sketch_project ∘ sketch_chain_bins == the pure-jnp pipeline."""
    v = aot.VARIANTS["demo"]
    x, r, delta, shift, fs = (jnp.asarray(a) for a in variant_args(v, "project_bins"))
    (s,) = model.sketch_project(x, r)
    (bins,) = model.sketch_chain_bins(s, delta, shift, fs)
    want = chain_bins_ref(project_ref(x, r), delta, shift, fs)
    mismatch = (np.asarray(bins) != np.asarray(want)).mean()
    assert mismatch < 1e-3, f"{mismatch:.2%} of bins differ"


def test_fused_model_matches_two_stage():
    v = aot.VARIANTS["demo"]
    x, r, delta, shift, fs = (jnp.asarray(a) for a in variant_args(v, "project_bins"))
    (s,) = model.sketch_project(x, r)
    (two,) = model.sketch_chain_bins(s, delta, shift, fs)
    (one,) = model.sketch_project_bins(x, r, delta, shift, fs)
    mismatch = (np.asarray(one) != np.asarray(two)).mean()
    assert mismatch < 1e-3
