"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Covers fixed shapes for every AOT variant plus hypothesis-driven shape /
value sweeps. All kernels run under ``interpret=True`` (CPU), so the
comparison is exact up to float-op ordering; we use tight tolerances and
additionally require *identical* integer bin ids away from bin boundaries
(floor is discontinuous, so boundary-adjacent disagreements at 1e-7 scale
are filtered, not tolerated silently).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.chain import chain_bins, level_masks
from compile.kernels.fused import project_bins
from compile.kernels.projection import project
from compile.kernels.ref import chain_bins_ref, project_bins_ref, project_ref

RNG = np.random.default_rng(0xC0FFEE)


def sign_matrix(d, k, rng, density=1 / 3):
    """Sparse ±1 sign matrix like the Eq.(2) hash family produces."""
    m = rng.choice([-1.0, 0.0, 1.0], size=(d, k), p=[density / 2, 1 - density, density / 2])
    return m.astype(np.float32)


def chain_params(k, l, rng):
    delta = (rng.uniform(0.5, 3.0, size=k)).astype(np.float32)
    shift = (rng.uniform(0.0, 1.0, size=k) * delta).astype(np.float32)
    fs = rng.integers(0, k, size=l).astype(np.int32)
    return delta, shift, fs


def assert_bins_match(got, want, s, delta):
    """Bin ids must match exactly except within eps of a bin boundary."""
    got = np.asarray(got)
    want = np.asarray(want)
    if np.array_equal(got, want):
        return
    # Tolerate off-by-one only where the prebin is ~on a boundary.
    diff = got != want
    frac_dist = np.abs(got - want)
    assert frac_dist[diff].max() <= 1, "bin ids differ by more than one"
    assert diff.mean() < 1e-3, f"too many boundary mismatches: {diff.mean():.2%}"


# ---------------------------------------------------------------- projection

VARIANT_SHAPES = [
    (8, 16, 4, 6),      # demo
    (256, 512, 50, 20), # gisette
    (1024, 2, 2, 20),   # osm (projection unused but shape-checked via K=D)
    (256, 100, 100, 20),# spamurl sketch-space
]


@pytest.mark.parametrize("b,d,k,l", VARIANT_SHAPES)
def test_project_matches_ref_variant_shapes(b, d, k, l):
    x = RNG.standard_normal((b, d)).astype(np.float32)
    r = sign_matrix(d, k, RNG)
    got = project(jnp.asarray(x), jnp.asarray(r))
    want = project_ref(jnp.asarray(x), jnp.asarray(r))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_project_non_divisible_tiles():
    x = RNG.standard_normal((37, 53)).astype(np.float32)
    r = sign_matrix(53, 7, RNG)
    got = project(jnp.asarray(x), jnp.asarray(r), tb=16, td=32)
    want = project_ref(jnp.asarray(x), jnp.asarray(r))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_project_zero_matrix():
    x = RNG.standard_normal((16, 8)).astype(np.float32)
    r = np.zeros((8, 4), dtype=np.float32)
    got = np.asarray(project(jnp.asarray(x), jnp.asarray(r)))
    assert (got == 0).all()


def test_project_identity_passthrough():
    x = RNG.standard_normal((8, 8)).astype(np.float32)
    r = np.eye(8, dtype=np.float32)
    got = np.asarray(project(jnp.asarray(x), jnp.asarray(r)))
    np.testing.assert_allclose(got, x, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 64),
    d=st.integers(1, 96),
    k=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_project_hypothesis_shapes(b, d, k, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, d)).astype(np.float32)
    r = sign_matrix(d, k, rng)
    got = project(jnp.asarray(x), jnp.asarray(r))
    want = project_ref(jnp.asarray(x), jnp.asarray(r))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- binning

def test_level_masks_partition():
    """m_first + m_rep must equal the one-hot of fs, disjointly."""
    rng = np.random.default_rng(7)
    k, l = 13, 29
    fs = jnp.asarray(rng.integers(0, k, size=l).astype(np.int32))
    mf, mr = level_masks(fs, k)
    mf, mr = np.asarray(mf), np.asarray(mr)
    onehot = np.eye(k, dtype=np.float32)[np.asarray(fs)]
    np.testing.assert_array_equal(mf + mr, onehot)
    assert (mf * mr == 0).all()
    # each feature's first occurrence is marked exactly once
    for f in np.unique(np.asarray(fs)):
        lv = np.where(np.asarray(fs) == f)[0]
        assert mf[lv[0], f] == 1.0
        assert mf[lv[1:], f].sum() == 0.0


@pytest.mark.parametrize("b,d,k,l", VARIANT_SHAPES)
def test_chain_bins_matches_ref_variant_shapes(b, d, k, l):
    s = (RNG.standard_normal((b, k)) * 4).astype(np.float32)
    delta, shift, fs = chain_params(k, l, RNG)
    got = chain_bins(jnp.asarray(s), jnp.asarray(delta), jnp.asarray(shift), jnp.asarray(fs))
    want = chain_bins_ref(jnp.asarray(s), jnp.asarray(delta), jnp.asarray(shift), jnp.asarray(fs))
    assert_bins_match(got, want, s, delta)


def test_chain_bins_repeated_feature_halves_bins():
    """Re-sampling a feature doubles prebin ⇒ bin widths halve each level."""
    s = np.array([[0.9], [1.9], [3.9]], dtype=np.float32)
    delta = np.array([2.0], dtype=np.float32)
    shift = np.array([0.0], dtype=np.float32)
    fs = np.array([0, 0, 0], dtype=np.int32)
    got = np.asarray(
        chain_bins(jnp.asarray(s), jnp.asarray(delta), jnp.asarray(shift), jnp.asarray(fs))
    )[:, :, 0]
    # level widths: 2.0, 1.0, 0.5
    np.testing.assert_array_equal(got[:, 0], [0, 0, 1])
    np.testing.assert_array_equal(got[:, 1], [0, 1, 3])
    np.testing.assert_array_equal(got[:, 2], [1, 3, 7])


def test_chain_bins_untouched_features_stay_zero():
    k, l = 6, 4
    s = (RNG.standard_normal((10, k)) * 3).astype(np.float32)
    delta, shift, _ = chain_params(k, l, RNG)
    fs = np.zeros(l, dtype=np.int32)  # only feature 0 ever sampled
    got = np.asarray(
        chain_bins(jnp.asarray(s), jnp.asarray(delta), jnp.asarray(shift), jnp.asarray(fs))
    )
    assert (got[:, :, 1:] == 0).all()


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 48),
    k=st.integers(1, 24),
    l=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_chain_bins_hypothesis(b, k, l, seed):
    rng = np.random.default_rng(seed)
    s = (rng.standard_normal((b, k)) * 5).astype(np.float32)
    delta, shift, fs = chain_params(k, l, rng)
    got = chain_bins(jnp.asarray(s), jnp.asarray(delta), jnp.asarray(shift), jnp.asarray(fs))
    want = chain_bins_ref(jnp.asarray(s), jnp.asarray(delta), jnp.asarray(shift), jnp.asarray(fs))
    assert_bins_match(got, want, s, delta)


# --------------------------------------------------------------------- fused

@pytest.mark.parametrize("b,d,k,l", [(8, 16, 4, 6), (64, 128, 25, 10)])
def test_fused_matches_ref(b, d, k, l):
    x = RNG.standard_normal((b, d)).astype(np.float32)
    r = sign_matrix(d, k, RNG)
    delta, shift, fs = chain_params(k, l, RNG)
    got = project_bins(
        jnp.asarray(x), jnp.asarray(r), jnp.asarray(delta), jnp.asarray(shift), jnp.asarray(fs)
    )
    want = project_bins_ref(
        jnp.asarray(x), jnp.asarray(r), jnp.asarray(delta), jnp.asarray(shift), jnp.asarray(fs)
    )
    assert_bins_match(got, want, None, delta)


def test_fused_equals_two_stage_pipeline():
    b, d, k, l = 32, 64, 10, 8
    x = RNG.standard_normal((b, d)).astype(np.float32)
    r = sign_matrix(d, k, RNG)
    delta, shift, fs = chain_params(k, l, RNG)
    s = project(jnp.asarray(x), jnp.asarray(r))
    two = chain_bins(s, jnp.asarray(delta), jnp.asarray(shift), jnp.asarray(fs))
    one = project_bins(
        jnp.asarray(x), jnp.asarray(r), jnp.asarray(delta), jnp.asarray(shift), jnp.asarray(fs)
    )
    assert_bins_match(one, two, None, delta)
