"""L2: the Sparx per-worker compute graph, composed from the L1 kernels.

Each function here is the body of one AOT artifact. The Rust coordinator
(L3) streams fixed-shape tiles of its partition through these compiled
modules on the PJRT CPU client; everything hash-table-shaped (CMS insert /
query, score aggregation across chains) stays in Rust.

Shapes are static per artifact (XLA requirement); ``aot.py`` emits one
variant per (B, D, K, L) the experiments need plus a tiny ``demo`` variant
that the Rust test-suite uses.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.chain import chain_bins
from .kernels.fused import project_bins
from .kernels.projection import project


def sketch_project(x: jnp.ndarray, r: jnp.ndarray):
    """Step 1 (Eq. 2): dense sketch projection. Returns a 1-tuple."""
    return (project(x, r),)


def sketch_chain_bins(s, delta, shift, fs):
    """Step 2 (Eq. 4): per-level K-dim bin ids. Returns a 1-tuple."""
    return (chain_bins(s, delta, shift, fs),)


def sketch_project_bins(x, r, delta, shift, fs):
    """Fused Step 1+2 — the §Perf candidate. Returns a 1-tuple."""
    return (project_bins(x, r, delta, shift, fs),)
