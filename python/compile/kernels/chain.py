"""L1 Pallas kernel: L-level incremental half-space binning (paper Eq. 4).

Given sketches ``s[B,K]``, initial bin widths ``delta[K]``, random shifts
``shift[K]`` and the chain's sampled feature per level ``fs[L]``, emit the
full K-dimensional integer bin id of every point at every level:
``bins[B,L,K]``.

The recurrence (cmuxstream ``Chain.fit``):

    first time f_l is sampled: prebin[:, f_l] = (s[:, f_l] + shift[f_l]) / delta[f_l]
    re-sampled:                prebin[:, f_l] = 2 * prebin[:, f_l] - shift[f_l] / delta[f_l]
    bins[:, l, :] = floor(prebin)

Vectorisation strategy: the data-dependent column update is turned into two
disjoint [L, K] masks precomputed from ``fs`` with pure jnp *inside the same
jit* (they are O(LK) scalar work, not worth a kernel):

    m_first[l] = onehot(fs[l]) if level l is the first occurrence of fs[l]
    m_rep[l]   = onehot(fs[l]) otherwise

so each level is ``prebin += m_first*(a - prebin) + m_rep*(b - prebin)``
with ``a = (s+shift)/delta`` (hoisted out of the loop — it never changes)
and ``b = 2*prebin - shift/delta``. The [TB, K] prebin state lives in VMEM
across all L levels; L is static (≤ 32) so the loop is unrolled at trace
time and Mosaic would software-pipeline the stores.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def level_masks(fs: jnp.ndarray, k: int):
    """[L,K] first-occurrence / repeat one-hot masks from ``fs`` [L] int32."""
    l = fs.shape[0]
    onehot = (fs[:, None] == jnp.arange(k, dtype=fs.dtype)[None, :]).astype(
        jnp.float32
    )
    eq = fs[:, None] == fs[None, :]  # [L, L]
    # first occurrence of fs[l] is at argmax(eq[l]) (first True)
    first = (jnp.argmax(eq, axis=1) == jnp.arange(l)).astype(jnp.float32)
    m_first = onehot * first[:, None]
    m_rep = onehot * (1.0 - first[:, None])
    return m_first, m_rep


def _bins_kernel(s_ref, delta_ref, shift_ref, mf_ref, mr_ref, o_ref, *, levels):
    s = s_ref[...]
    delta = delta_ref[...]          # [1, K]
    shift = shift_ref[...]          # [1, K]
    a = (s + shift) / delta         # invariant across levels
    c = shift / delta               # invariant across levels
    prebin = jnp.zeros_like(s)
    for lvl in range(levels):       # static unroll; L ≤ 32
        mf = mf_ref[lvl, :][None, :]
        mr = mr_ref[lvl, :][None, :]
        b = 2.0 * prebin - c
        prebin = prebin + mf * (a - prebin) + mr * (b - prebin)
        o_ref[:, lvl, :] = jnp.floor(prebin).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tb",))
def chain_bins(
    s: jnp.ndarray,
    delta: jnp.ndarray,
    shift: jnp.ndarray,
    fs: jnp.ndarray,
    *,
    tb: int = 256,
):
    """Pallas L-level binning: returns ``bins[B, L, K]`` int32."""
    b, k = s.shape
    l = fs.shape[0]
    while b % tb != 0:
        tb -= 1
    m_first, m_rep = level_masks(fs, k)
    grid = (b // tb,)
    return pl.pallas_call(
        functools.partial(_bins_kernel, levels=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((l, k), lambda i: (0, 0)),
            pl.BlockSpec((l, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, l, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, k), jnp.int32),
        interpret=True,
    )(
        s.astype(jnp.float32),
        delta.astype(jnp.float32)[None, :],
        shift.astype(jnp.float32)[None, :],
        m_first,
        m_rep,
    )
