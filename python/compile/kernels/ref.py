"""Pure-jnp oracle for the Sparx numeric hot path.

These reference implementations define the semantics the Pallas kernels
(and the Rust native backend) must match bit-for-bit (up to float
associativity):

  * ``project_ref``      — sketch projection  s = x @ R           (Eq. 1/2)
  * ``chain_bins_ref``   — L-level incremental half-space binning (Eq. 4)
  * ``project_bins_ref`` — the fused composition.

Binning semantics (xStream ``Chain.fit``): per level ``l`` with sampled
feature ``f_l``::

    if first occurrence of f_l:  prebin[:, f_l] = (s[:, f_l] + shift[f_l]) / delta[f_l]
    else:                        prebin[:, f_l] = 2 * prebin[:, f_l] - shift[f_l] / delta[f_l]
    bins[l] = floor(prebin)                       # full K-dim bin id

``shift[k] ~ U(0, delta[k])`` is the per-projected-feature random shift;
the recurrence keeps the shifted origin consistent while halving the bin
width of the re-sampled feature, exactly as in the cmuxstream reference
code and Eq. (4) of the paper.
"""

from __future__ import annotations

import jax.numpy as jnp


def project_ref(x: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Dense sketch projection: ``s[b,k] = sum_d x[b,d] * r[d,k]``.

    ``r`` holds the hashed sparse-sign entries (−1/0/+1 scaled); hashing
    itself happens outside the compiled graph (Rust / numpy), because it
    is string work, not MXU work.
    """
    return jnp.dot(x.astype(jnp.float32), r.astype(jnp.float32))


def chain_bins_ref(
    s: jnp.ndarray,       # [B, K] float32 sketches
    delta: jnp.ndarray,   # [K]    float32 initial bin widths (> 0)
    shift: jnp.ndarray,   # [K]    float32 random shifts in (0, delta)
    fs: jnp.ndarray,      # [L]    int32   sampled feature per level
) -> jnp.ndarray:
    """Reference L-level incremental binning. Returns [B, L, K] int32."""
    b, k = s.shape
    l = fs.shape[0]
    prebin = jnp.zeros((b, k), dtype=jnp.float32)
    seen = jnp.zeros((k,), dtype=jnp.bool_)
    outs = []
    for lvl in range(l):
        f = fs[lvl]
        first = ~seen[f]
        new_col = jnp.where(
            first,
            (s[:, f] + shift[f]) / delta[f],
            2.0 * prebin[:, f] - shift[f] / delta[f],
        )
        prebin = prebin.at[:, f].set(new_col)
        seen = seen.at[f].set(True)
        outs.append(jnp.floor(prebin).astype(jnp.int32))
    return jnp.stack(outs, axis=1)


def project_bins_ref(x, r, delta, shift, fs):
    """Fused projection + binning reference."""
    return chain_bins_ref(project_ref(x, r), delta, shift, fs)


def score_support_ref(
    s: jnp.ndarray,
    delta: jnp.ndarray,
    shift: jnp.ndarray,
    fs: jnp.ndarray,
) -> jnp.ndarray:
    """Scoring uses the identical bin ids as fitting (Sec. 3.3)."""
    return chain_bins_ref(s, delta, shift, fs)
