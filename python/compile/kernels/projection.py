"""L1 Pallas kernel: tiled sketch-projection matmul  s = x @ R.

The projection (paper Eq. 1/2) is the dense numeric half of Sparx Step 1.
The hash-generated sign matrix ``R`` ([D, K], entries in {-1, 0, +1}) is
materialised outside the graph (Rust / numpy) and fed as an operand, so the
same compiled artifact serves any seed set.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the output tile
``[TB, K]`` stays resident in VMEM while the contraction dimension ``D`` is
streamed through in ``TD`` blocks — the BlockSpec index maps express the
HBM→VMEM schedule that a CUDA implementation would express with
threadblocks + shared memory. ``interpret=True`` everywhere: the CPU PJRT
plugin cannot execute Mosaic custom-calls, so the kernel lowers to plain
HLO and the real-TPU story is argued analytically in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, r_ref, o_ref):
    """One (TB, K) output tile; grid dim 1 walks the D blocks."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], r_ref[...], preferred_element_type=jnp.float32
    )


def _pick_tile(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is ≤ target (keeps grids exact)."""
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tb", "td"))
def project(x: jnp.ndarray, r: jnp.ndarray, *, tb: int = 128, td: int = 512):
    """Pallas-tiled ``x[B,D] @ r[D,K] -> s[B,K]`` (float32).

    ``K`` is small (≤ 128 in every paper config) so a full-K tile is kept
    in VMEM; ``B`` and ``D`` are tiled to ``tb``/``td`` (rounded down to
    divisors, so callers may pass any shape).
    """
    b, d = x.shape
    d2, k = r.shape
    assert d == d2, f"contraction mismatch {d} vs {d2}"
    tb = _pick_tile(b, tb)
    td = _pick_tile(d, td)
    grid = (b // tb, d // td)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, td), lambda i, j: (i, j)),
            pl.BlockSpec((td, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tb, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), r.astype(jnp.float32))
