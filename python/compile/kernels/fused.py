"""L1 Pallas kernel: fused projection + binning in a single VMEM pass.

For moderate ``D`` (Gisette-scale, a few thousand) the sketch tile
``s = x_tile @ R`` never needs to round-trip to HBM between Step 1 and
Step 2 of Sparx: this kernel computes the [TB, K] sketch tile on the MXU
and immediately runs the L-level binning recurrence on it while it is
still VMEM-resident, writing only the int32 bin ids back out.

This is the §Perf "fusion" candidate measured against the two-kernel
pipeline in EXPERIMENTS.md; the unfused pair remains the default because
it also serves the no-projection (OSM) and sparse-native (SpamURL) paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .chain import level_masks


def _fused_kernel(x_ref, r_ref, delta_ref, shift_ref, mf_ref, mr_ref, o_ref, *, levels):
    s = jnp.dot(x_ref[...], r_ref[...], preferred_element_type=jnp.float32)
    delta = delta_ref[...]
    shift = shift_ref[...]
    a = (s + shift) / delta
    c = shift / delta
    prebin = jnp.zeros_like(s)
    for lvl in range(levels):
        mf = mf_ref[lvl, :][None, :]
        mr = mr_ref[lvl, :][None, :]
        b = 2.0 * prebin - c
        prebin = prebin + mf * (a - prebin) + mr * (b - prebin)
        o_ref[:, lvl, :] = jnp.floor(prebin).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tb",))
def project_bins(
    x: jnp.ndarray,
    r: jnp.ndarray,
    delta: jnp.ndarray,
    shift: jnp.ndarray,
    fs: jnp.ndarray,
    *,
    tb: int = 128,
):
    """Fused ``bins = floor-binning(x @ r)`` → [B, L, K] int32.

    Keeps the full contraction dimension in one block (suitable for
    D ≤ a few thousand; larger D should use the two-kernel pipeline).
    """
    b, d = x.shape
    _, k = r.shape
    l = fs.shape[0]
    while b % tb != 0:
        tb -= 1
    m_first, m_rep = level_masks(fs, k)
    grid = (b // tb,)
    return pl.pallas_call(
        functools.partial(_fused_kernel, levels=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((d, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((l, k), lambda i: (0, 0)),
            pl.BlockSpec((l, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, l, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, k), jnp.int32),
        interpret=True,
    )(
        x.astype(jnp.float32),
        r.astype(jnp.float32),
        delta.astype(jnp.float32)[None, :],
        shift.astype(jnp.float32)[None, :],
        m_first,
        m_rep,
    )
