"""AOT: lower the L2 graphs to HLO **text** + a manifest for the Rust side.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits ``<kind>_<variant>.hlo.txt`` per entry in ``VARIANTS`` plus
``manifest.json`` describing every artifact's operand shapes, which
``rust/src/runtime/artifacts.rs`` deserialises.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# variant -> (B, D, K, L). B is the tile size the Rust hot path pads to.
VARIANTS = {
    # tiny shapes for the Rust test-suite / quickstart
    "demo": dict(b=8, d=16, k=4, l=6),
    # gisette-like: small-n / large-d (scaled: see DESIGN.md substitutions)
    "gisette": dict(b=256, d=512, k=50, l=20),
    # osm-like: raw 2-d coords, no projection (paper §4.1.5: K not applied)
    "osm": dict(b=1024, d=2, k=2, l=20),
    # spamurl-like: sparse projection happens natively in Rust (D=200k is
    # not dense-matmul work); binning of the K=100 sketches runs here.
    "spamurl": dict(b=256, d=100, k=100, l=20),
}

# which artifact kinds each variant needs
KINDS = {
    "demo": ("project", "chain_bins", "project_bins"),
    "gisette": ("project", "chain_bins", "project_bins"),
    "osm": ("chain_bins",),
    "spamurl": ("chain_bins",),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def specs(variant: dict, kind: str):
    b, d, k, l = variant["b"], variant["d"], variant["k"], variant["l"]
    f32, i32 = jnp.float32, jnp.int32
    x = jax.ShapeDtypeStruct((b, d), f32)
    r = jax.ShapeDtypeStruct((d, k), f32)
    s = jax.ShapeDtypeStruct((b, k), f32)
    vk = jax.ShapeDtypeStruct((k,), f32)
    fs = jax.ShapeDtypeStruct((l,), i32)
    if kind == "project":
        return model.sketch_project, (x, r)
    if kind == "chain_bins":
        return model.sketch_chain_bins, (s, vk, vk, fs)
    if kind == "project_bins":
        return model.sketch_project_bins, (x, r, vk, vk, fs)
    raise ValueError(kind)


def lower_one(name: str, variant: dict, kind: str, out_dir: str) -> dict:
    fn, args = specs(variant, kind)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{kind}_{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    return {
        "name": name,
        "kind": kind,
        "file": fname,
        "b": variant["b"],
        "d": variant["d"],
        "k": variant["k"],
        "l": variant["l"],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file target (ignored path, kept for Makefile compat)")
    ap.add_argument(
        "--variants", default=None, help="comma-separated subset of variants"
    )
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    wanted = args.variants.split(",") if args.variants else list(VARIANTS)
    entries = []
    for name in wanted:
        variant = VARIANTS[name]
        for kind in KINDS[name]:
            entry = lower_one(name, variant, kind, out_dir)
            entries.append(entry)
            print(f"wrote {entry['file']}  (b={entry['b']} d={entry['d']} k={entry['k']} l={entry['l']})")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"artifacts": entries}, f, indent=2)
    # Makefile stamp: the legacy --out path, if requested
    if args.out is not None:
        with open(args.out, "w") as f:
            f.write("\n".join(e["file"] for e in entries) + "\n")
    print(f"manifest: {len(entries)} artifacts in {out_dir}")


if __name__ == "__main__":
    main()
